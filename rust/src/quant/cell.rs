//! Rust-native packed BN-LSTM cell — the deployment inference engine.
//!
//! This is the software twin of the paper's accelerator datapath: weights
//! live as bit planes (1-2 bits each), the "multiplier" is a sign-select,
//! and the gate tail runs in f32. It exists so the repo can demonstrate
//! the §6 memory/speed win end-to-end on a CPU — the serving bench
//! compares this path against the PJRT dense-f32 executable.
//!
//! One-hot (token) inputs exploit the same trick as the ASIC's weight
//! SRAM addressing: the x-path matmul of a one-hot vector is a single
//! packed-row gather, not a GEMV.

use anyhow::{bail, Context, Result};

use super::gemm::{gemm_binary_lut, gemm_binary_lut_cols, gemm_ternary_lut,
                  gemm_ternary_lut_cols, gemm_ternary_planes,
                  gemm_ternary_planes_cols, GemmScratch};
use super::gemv_lut::{gemv_binary_lut, gemv_ternary_lut, LutScratch};
use super::simd::SharedOut;
use super::pack::{words_per_col, PackedBinary, PackedTernary};
use super::planes::{gemv_ternary_planes, TernaryPlanes};
use crate::runtime::Session;

/// Packed weight matrix, any precision/layout the engine serves from.
///
/// Cloning is cheap by design: every layout stores its plane words
/// behind `Arc`, so a clone bumps a refcount instead of copying bytes —
/// the mechanism the sharded serving cluster uses to run N engines over
/// one resident weight set ([`Packed::plane_ptr`] /
/// [`Packed::plane_owners`] let tests assert it).
#[derive(Clone)]
pub enum Packed {
    Binary(PackedBinary),
    Ternary(PackedTernary),
    /// Ternary as precomputed pos/neg selector planes (the wide-batch
    /// GEMV layout; see [`super::planes`]).
    Planes(TernaryPlanes),
}

impl Packed {
    pub fn rows(&self) -> usize {
        match self {
            Packed::Binary(b) => b.rows,
            Packed::Ternary(t) => t.rows,
            Packed::Planes(p) => p.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Packed::Binary(b) => b.cols,
            Packed::Ternary(t) => t.cols,
            Packed::Planes(p) => p.cols,
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            Packed::Binary(b) => b.packed_bytes(),
            Packed::Ternary(t) => t.packed_bytes(),
            Packed::Planes(p) => p.packed_bytes(),
        }
    }

    /// Address of the primary plane allocation (sign plane for the LUT
    /// layouts, pos plane for bit planes) — identical across shared
    /// clones.
    pub fn plane_ptr(&self) -> *const u64 {
        match self {
            Packed::Binary(b) => b.plane_ptr(),
            Packed::Ternary(t) => t.plane_ptr(),
            Packed::Planes(p) => p.plane_ptr(),
        }
    }

    /// Live owners of the primary plane allocation (1 = unshared).
    pub fn plane_owners(&self) -> usize {
        match self {
            Packed::Binary(b) => b.plane_owners(),
            Packed::Ternary(t) => t.plane_owners(),
            Packed::Planes(p) => p.plane_owners(),
        }
    }

    /// Convert to the bit-plane GEMV layout. Binary matrices stay as-is
    /// (the binary LUT GEMV already streams one plane byte per group).
    pub fn to_planes(self) -> Packed {
        match self {
            Packed::Ternary(t) => Packed::Planes(TernaryPlanes::from_packed(&t)),
            other => other,
        }
    }

    /// Multiplier-free GEMV: y = xᵀW (LUT kernels; y is overwritten).
    pub fn gemv(&self, x: &[f32], y: &mut [f32], scratch: &mut LutScratch) {
        match self {
            Packed::Binary(b) => gemv_binary_lut(b, x, y, scratch),
            Packed::Ternary(t) => gemv_ternary_lut(t, x, y, scratch),
            Packed::Planes(p) => gemv_ternary_planes(p, x, y, scratch),
        }
    }

    /// Batched multiplier-free GEMM: Y = X·W for X row-major
    /// `(batch, rows)`, Y row-major `(batch, cols)` (overwritten). Each
    /// packed weight word is streamed **once** for all batch rows; every
    /// output row is bit-identical to [`Packed::gemv`] on that row (see
    /// [`super::gemm`]).
    pub fn gemm(&self, x: &[f32], batch: usize, y: &mut [f32],
                scratch: &mut GemmScratch) {
        match self {
            Packed::Binary(b) => gemm_binary_lut(b, x, batch, y, scratch),
            Packed::Ternary(t) => gemm_ternary_lut(t, x, batch, y, scratch),
            Packed::Planes(p) => gemm_ternary_planes(p, x, batch, y, scratch),
        }
    }

    /// Column shard `[c0, c1)` of [`Packed::gemm`], streaming only those
    /// columns' packed plane bytes — the unit of work the engine's
    /// thread pool fans out. A column's math never depends on which
    /// shard computes it, so any shard split reassembles the one-shard
    /// result bit for bit.
    ///
    /// # Safety
    /// `out` must view a live row-major `(batch, cols())` buffer, and no
    /// concurrent shard may overlap this one's column range.
    pub unsafe fn gemm_cols(&self, x: &[f32], batch: usize, c0: usize,
                            c1: usize, out: SharedOut,
                            scratch: &mut GemmScratch) {
        // SAFETY: forwarded from this function's contract.
        unsafe {
            match self {
                Packed::Binary(b) => {
                    gemm_binary_lut_cols(b, x, batch, c0, c1, out, scratch)
                }
                Packed::Ternary(t) => {
                    gemm_ternary_lut_cols(t, x, batch, c0, c1, out, scratch)
                }
                Packed::Planes(p) => {
                    gemm_ternary_planes_cols(p, x, batch, c0, c1, out, scratch)
                }
            }
        }
    }

    /// Batched one-hot gather: row `rows[b]` of the matrix into row `b`
    /// of the `(rows.len(), cols)` output block (overwritten) — the
    /// token x-path of a whole decode batch as `rows.len()` packed-row
    /// gathers, no GEMM at all.
    pub fn gather_rows(&self, rows: &[usize], y: &mut [f32]) {
        let cols = self.cols();
        debug_assert_eq!(y.len(), rows.len() * cols);
        y.fill(0.0);
        for (b, &r) in rows.iter().enumerate() {
            self.add_row(r, &mut y[b * cols..(b + 1) * cols]);
        }
    }

    /// y += row r of the matrix (the one-hot x-path: a one-hot GEMV is a
    /// single packed-row gather, exactly the accelerator's weight-SRAM
    /// addressing trick).
    pub fn add_row(&self, r: usize, y: &mut [f32]) {
        match self {
            Packed::Binary(b) => {
                let wpc = words_per_col(b.rows);
                let (w, bit) = (r / 64, r % 64);
                for c in 0..b.cols {
                    let sign = (b.sign[c * wpc + w] >> bit) & 1;
                    y[c] += if sign == 1 { b.alpha } else { -b.alpha };
                }
            }
            Packed::Ternary(t) => {
                let wpc = words_per_col(t.rows);
                let (w, bit) = (r / 64, r % 64);
                for c in 0..t.cols {
                    if (t.mask[c * wpc + w] >> bit) & 1 == 1 {
                        let sign = (t.sign[c * wpc + w] >> bit) & 1;
                        y[c] += if sign == 1 { t.alpha } else { -t.alpha };
                    }
                }
            }
            Packed::Planes(p) => {
                let wpc = words_per_col(p.rows);
                let (w, bit) = (r / 64, r % 64);
                for c in 0..p.cols {
                    let idx = c * wpc + w;
                    if (p.pos[idx] >> bit) & 1 == 1 {
                        y[c] += p.alpha;
                    } else if (p.neg[idx] >> bit) & 1 == 1 {
                        y[c] -= p.alpha;
                    }
                }
            }
        }
    }
}

/// The packed cell: quantized weights + folded BN statistics + bias.
pub struct PackedLstmCell {
    pub wx: Packed,
    pub wh: Packed,
    /// folded BN: pre = (x@wx)*scale_x + shift_x + (h@wh)*scale_h +
    /// shift_h + bias; all (4H,).
    pub scale_x: Vec<f32>,
    pub shift_x: Vec<f32>,
    pub scale_h: Vec<f32>,
    pub shift_h: Vec<f32>,
    pub bias: Vec<f32>,
    pub hidden: usize,
    // scratch buffers (reused across steps; the hot loop allocates nothing
    // once the widest batch has been seen)
    xw: Vec<f32>,
    hw: Vec<f32>,
    lut: LutScratch,
    xw_b: Vec<f32>,
    hw_b: Vec<f32>,
    gemm: GemmScratch,
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl Clone for PackedLstmCell {
    /// Cheap clone for shard fan-out: the packed matrices alias the
    /// source's `Arc`-backed plane allocations (no weight bytes copied),
    /// the small folded-BN vectors are copied, and the scratch buffers
    /// start fresh — each clone steps independently on its own scratch.
    fn clone(&self) -> Self {
        let n4 = 4 * self.hidden;
        Self {
            wx: self.wx.clone(),
            wh: self.wh.clone(),
            scale_x: self.scale_x.clone(),
            shift_x: self.shift_x.clone(),
            scale_h: self.scale_h.clone(),
            shift_h: self.shift_h.clone(),
            bias: self.bias.clone(),
            hidden: self.hidden,
            xw: vec![0.0; n4],
            hw: vec![0.0; n4],
            lut: LutScratch::default(),
            xw_b: vec![],
            hw_b: vec![],
            gemm: GemmScratch::default(),
        }
    }
}

impl PackedLstmCell {
    pub fn new(wx: Packed, wh: Packed, scale_x: Vec<f32>, shift_x: Vec<f32>,
               scale_h: Vec<f32>, shift_h: Vec<f32>, bias: Vec<f32>)
               -> Result<Self> {
        let n4 = wx.cols();
        if wh.cols() != n4 || n4 % 4 != 0 {
            bail!("gate width mismatch: wx {} wh {}", n4, wh.cols());
        }
        let hidden = n4 / 4;
        if wh.rows() != hidden {
            bail!("wh rows {} != hidden {hidden}", wh.rows());
        }
        for (nm, v) in [("scale_x", &scale_x), ("shift_x", &shift_x),
                        ("scale_h", &scale_h), ("shift_h", &shift_h),
                        ("bias", &bias)] {
            if v.len() != n4 {
                bail!("{nm} length {} != {n4}", v.len());
            }
        }
        Ok(Self {
            wx, wh, scale_x, shift_x, scale_h, shift_h, bias, hidden,
            xw: vec![0.0; n4],
            hw: vec![0.0; n4],
            lut: LutScratch::default(),
            xw_b: vec![],
            hw_b: vec![],
            gemm: GemmScratch::default(),
        })
    }

    /// Build from a live session's params/state (running BN statistics)
    /// plus freshly sampled packed weights.
    pub fn from_session(sess: &Session, seed: u64) -> Result<Self> {
        use crate::model::export::export_packed;
        use crate::model::PackedMatrix;
        let model = export_packed(sess, seed)?;
        let take = |name: &str| -> Result<Packed> {
            match model.matrices.get(name) {
                Some(PackedMatrix::Binary(b)) => Ok(Packed::Binary(b.clone())),
                Some(PackedMatrix::Ternary(t)) => Ok(Packed::Ternary(t.clone())),
                Some(PackedMatrix::Dense { .. }) => {
                    bail!("fp artifact has no packed deployment path")
                }
                None => bail!("missing packed matrix {name}"),
            }
        };
        let wx = take("l0/wx")?;
        let wh = take("l0/wh")?;
        let bias = sess.params.get_f32("l0/b")?;
        let n4 = bias.len();
        let fold = |phi: Vec<f32>, rm: Vec<f32>, rv: Vec<f32>| {
            let mut scale = vec![0.0f32; n4];
            let mut shift = vec![0.0f32; n4];
            for i in 0..n4 {
                scale[i] = phi[i] / (rv[i] + 1e-5).sqrt();
                shift[i] = -rm[i] * scale[i];
            }
            (scale, shift)
        };
        let (scale_x, shift_x) = fold(
            sess.params.get_f32("l0/phi_x").context("phi_x (BN model only)")?,
            sess.state.get_f32("l0/rm_x")?,
            sess.state.get_f32("l0/rv_x")?,
        );
        let (scale_h, shift_h) = fold(
            sess.params.get_f32("l0/phi_h")?,
            sess.state.get_f32("l0/rm_h")?,
            sess.state.get_f32("l0/rv_h")?,
        );
        Self::new(wx, wh, scale_x, shift_x, scale_h, shift_h, bias)
    }

    /// One step with a token (one-hot) input. Gate order [i, f, g, o].
    pub fn step_token(&mut self, token: usize, h: &mut [f32], c: &mut [f32]) {
        debug_assert_eq!(h.len(), self.hidden);
        self.xw.fill(0.0);
        self.wx.add_row(token, &mut self.xw);
        self.wh.gemv(h, &mut self.hw, &mut self.lut);
        self.tail(h, c);
    }

    /// One step with a dense input vector.
    pub fn step_dense(&mut self, x: &[f32], h: &mut [f32], c: &mut [f32]) {
        self.wx.gemv(x, &mut self.xw, &mut self.lut);
        self.wh.gemv(h, &mut self.hw, &mut self.lut);
        self.tail(h, c);
    }

    /// One step for a whole batch of token streams at once, on this
    /// cell's own scratch. `h`/`c` are row-major `(tokens.len(),
    /// hidden)` blocks holding the *active* slots' state, updated in
    /// place.
    ///
    /// The x-path is a batched one-hot gather (one packed-row gather per
    /// stream), the h-path a single batched GEMM that streams the packed
    /// `wh` planes once for every stream, and the gate tail runs per row.
    /// Each row's result is bit-identical to [`Self::step_token`] on
    /// that stream alone.
    ///
    /// The serving engine does **not** call this: `PackedBackend`
    /// re-assembles the same gather → [`Packed::gemm_cols`] →
    /// [`Self::gate_tail_rows`] sequence with pool-sharded stages and
    /// its own buffers. Both assemblies are anchored to the same
    /// reference — each is tested bit-identical to [`Self::step_token`]
    /// per stream — so they cannot silently diverge; this method remains
    /// the single-scratch library API (and the engine-free way to test
    /// the batched kernels through the cell).
    pub fn step_tokens(&mut self, tokens: &[usize], h: &mut [f32],
                       c: &mut [f32]) {
        let batch = tokens.len();
        if batch == 0 {
            return;
        }
        let n4 = 4 * self.hidden;
        debug_assert_eq!(h.len(), batch * self.hidden);
        debug_assert_eq!(c.len(), batch * self.hidden);
        if self.xw_b.len() < batch * n4 {
            self.xw_b.resize(batch * n4, 0.0);
            self.hw_b.resize(batch * n4, 0.0);
        }
        self.wx.gather_rows(tokens, &mut self.xw_b[..batch * n4]);
        self.wh.gemm(h, batch, &mut self.hw_b[..batch * n4], &mut self.gemm);
        // one tail implementation for this path and the engine's sharded
        // path; the take/put-back frees the field borrow for the &self
        // call and is just two pointer swaps
        let mut xw_b = std::mem::take(&mut self.xw_b);
        self.gate_tail_rows(&mut xw_b[..batch * n4],
                            &self.hw_b[..batch * n4], h, c);
        self.xw_b = xw_b;
    }

    fn tail(&mut self, h: &mut [f32], c: &mut [f32]) {
        gate_tail(&mut self.xw, &self.hw, &self.scale_x, &self.shift_x,
                  &self.scale_h, &self.shift_h, &self.bias, self.hidden, h, c);
    }

    /// Folded-BN gate tail over a row-major block of streams: `xw` is a
    /// `(rows, 4H)` preactivation block (consumed in place), `hw` its
    /// recurrent counterpart, `h`/`c` the matching `(rows, H)` state
    /// rows, updated in place. Row count is inferred from `xw.len()`.
    ///
    /// Each row is independent and walks the identical op sequence as
    /// [`Self::step_token`]'s tail, so the engine can shard rows across
    /// worker threads without changing a single state bit.
    pub fn gate_tail_rows(&self, xw: &mut [f32], hw: &[f32], h: &mut [f32],
                          c: &mut [f32]) {
        let n4 = 4 * self.hidden;
        debug_assert_eq!(xw.len() % n4, 0);
        let rows = xw.len() / n4;
        debug_assert_eq!(hw.len(), rows * n4);
        debug_assert_eq!(h.len(), rows * self.hidden);
        debug_assert_eq!(c.len(), rows * self.hidden);
        for b in 0..rows {
            gate_tail(&mut xw[b * n4..(b + 1) * n4],
                      &hw[b * n4..(b + 1) * n4],
                      &self.scale_x, &self.shift_x,
                      &self.scale_h, &self.shift_h, &self.bias, self.hidden,
                      &mut h[b * self.hidden..(b + 1) * self.hidden],
                      &mut c[b * self.hidden..(b + 1) * self.hidden]);
        }
    }

    /// Total packed weight bytes (the deployment footprint).
    pub fn weight_bytes(&self) -> usize {
        self.wx.bytes() + self.wh.bytes()
    }
}

/// The folded-BN gate tail over one stream's preactivations: identical
/// op sequence whether the stream was stepped alone or in a batch.
#[allow(clippy::too_many_arguments)]
fn gate_tail(xw: &mut [f32], hw: &[f32], scale_x: &[f32], shift_x: &[f32],
             scale_h: &[f32], shift_h: &[f32], bias: &[f32], hid: usize,
             h: &mut [f32], c: &mut [f32]) {
    for j in 0..4 * hid {
        xw[j] = xw[j] * scale_x[j] + shift_x[j]
            + hw[j] * scale_h[j] + shift_h[j]
            + bias[j];
    }
    for k in 0..hid {
        let i = sigmoid(xw[k]);
        let f = sigmoid(xw[hid + k]);
        let g = xw[2 * hid + k].tanh();
        let o = sigmoid(xw[3 * hid + k]);
        c[k] = f * c[k] + i * g;
        h[k] = o * c[k].tanh();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gemv_f32;
    use crate::util::Rng;

    fn mk_cell(vocab: usize, hid: usize, seed: u64) -> (PackedLstmCell, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let alpha = 0.11;
        let wx_dense: Vec<f32> = (0..vocab * 4 * hid)
            .map(|_| [0.0, alpha, -alpha][rng.below_usize(3)])
            .collect();
        let wh_dense: Vec<f32> = (0..hid * 4 * hid)
            .map(|_| [0.0, alpha, -alpha][rng.below_usize(3)])
            .collect();
        let n4 = 4 * hid;
        let cell = PackedLstmCell::new(
            Packed::Ternary(PackedTernary::pack(&wx_dense, vocab, n4, alpha)),
            Packed::Ternary(PackedTernary::pack(&wh_dense, hid, n4, alpha)),
            vec![1.0; n4], vec![0.0; n4], vec![1.0; n4], vec![0.0; n4],
            (0..n4).map(|_| rng.normal_f32() * 0.1).collect(),
        )
        .unwrap();
        (cell, wx_dense, wh_dense)
    }

    /// dense f32 reference of the same cell math.
    fn ref_step(wx: &[f32], wh: &[f32], bias: &[f32], vocab: usize, hid: usize,
                token: usize, h: &mut Vec<f32>, c: &mut Vec<f32>) {
        let n4 = 4 * hid;
        let mut x = vec![0.0f32; vocab];
        x[token] = 1.0;
        let mut xw = vec![0.0; n4];
        let mut hw = vec![0.0; n4];
        gemv_f32(wx, vocab, n4, &x, &mut xw);
        gemv_f32(wh, hid, n4, h, &mut hw);
        let sig = |x: f32| 1.0 / (1.0 + (-x).exp());
        let mut hn = vec![0.0; hid];
        for k in 0..hid {
            let pre = |j: usize| xw[j] + hw[j] + bias[j];
            let i = sig(pre(k));
            let f = sig(pre(hid + k));
            let g = pre(2 * hid + k).tanh();
            let o = sig(pre(3 * hid + k));
            c[k] = f * c[k] + i * g;
            hn[k] = o * c[k].tanh();
        }
        *h = hn;
    }

    #[test]
    fn matches_dense_reference_over_trajectory() {
        let (mut cell, wx, wh, ) = mk_cell(50, 32, 9);
        let bias = cell.bias.clone();
        let mut h = vec![0.0f32; 32];
        let mut c = vec![0.0f32; 32];
        let mut hr = vec![0.0f32; 32];
        let mut cr = vec![0.0f32; 32];
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let tok = rng.below_usize(50);
            cell.step_token(tok, &mut h, &mut c);
            ref_step(&wx, &wh, &bias, 50, 32, tok, &mut hr, &mut cr);
            for k in 0..32 {
                assert!((h[k] - hr[k]).abs() < 1e-4, "h[{k}]");
                assert!((c[k] - cr[k]).abs() < 1e-4, "c[{k}]");
            }
        }
    }

    #[test]
    fn dense_and_token_paths_agree() {
        let (mut cell, _, _) = mk_cell(30, 16, 13);
        let mut h1 = vec![0.0f32; 16];
        let mut c1 = vec![0.0f32; 16];
        cell.step_token(7, &mut h1, &mut c1);
        let (mut cell2, _, _) = mk_cell(30, 16, 13);
        let mut x = vec![0.0f32; 30];
        x[7] = 1.0;
        let mut h2 = vec![0.0f32; 16];
        let mut c2 = vec![0.0f32; 16];
        cell2.step_dense(&x, &mut h2, &mut c2);
        for k in 0..16 {
            assert!((h1[k] - h2[k]).abs() < 1e-5);
        }
    }

    #[test]
    fn state_stays_bounded() {
        let (mut cell, _, _) = mk_cell(40, 24, 17);
        let mut h = vec![0.0f32; 24];
        let mut c = vec![0.0f32; 24];
        let mut rng = Rng::new(19);
        for _ in 0..500 {
            cell.step_token(rng.below_usize(40), &mut h, &mut c);
        }
        assert!(h.iter().all(|v| v.abs() <= 1.0 && v.is_finite()));
        assert!(c.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn planes_cell_matches_lut_cell_bitwise() {
        // the PackedPlanes engine backend relies on the plane GEMV being
        // bit-identical to the LUT GEMV (same table, same add order).
        let (mut lut_cell, wx, wh) = mk_cell(40, 24, 23);
        let alpha = 0.11;
        let n4 = 4 * 24;
        let mut planes_cell = PackedLstmCell::new(
            Packed::Ternary(PackedTernary::pack(&wx, 40, n4, alpha)).to_planes(),
            Packed::Ternary(PackedTernary::pack(&wh, 24, n4, alpha)).to_planes(),
            vec![1.0; n4], vec![0.0; n4], vec![1.0; n4], vec![0.0; n4],
            lut_cell.bias.clone(),
        )
        .unwrap();
        let (mut h1, mut c1) = (vec![0.0f32; 24], vec![0.0f32; 24]);
        let (mut h2, mut c2) = (vec![0.0f32; 24], vec![0.0f32; 24]);
        let mut rng = Rng::new(29);
        for _ in 0..30 {
            let tok = rng.below_usize(40);
            lut_cell.step_token(tok, &mut h1, &mut c1);
            planes_cell.step_token(tok, &mut h2, &mut c2);
            for k in 0..24 {
                assert_eq!(h1[k].to_bits(), h2[k].to_bits(), "h[{k}]");
                assert_eq!(c1[k].to_bits(), c2[k].to_bits(), "c[{k}]");
            }
        }
    }

    #[test]
    fn batched_step_matches_per_stream_bitwise() {
        // two cells with identical weights: one stepped per stream, one
        // stepped through the batched path — trajectories must not
        // diverge by a single bit, for every packing layout.
        for planes in [false, true] {
            let (mut a, wx, wh) = mk_cell(30, 20, 31);
            let n4 = 4 * 20;
            let mk = |d: &[f32], rows: usize| {
                let p = Packed::Ternary(PackedTernary::pack(d, rows, n4, 0.11));
                if planes { p.to_planes() } else { p }
            };
            let mut b = PackedLstmCell::new(
                mk(&wx, 30), mk(&wh, 20),
                vec![1.0; n4], vec![0.0; n4], vec![1.0; n4], vec![0.0; n4],
                a.bias.clone(),
            )
            .unwrap();
            if planes {
                a = PackedLstmCell::new(
                    mk(&wx, 30), mk(&wh, 20),
                    vec![1.0; n4], vec![0.0; n4], vec![1.0; n4], vec![0.0; n4],
                    b.bias.clone(),
                )
                .unwrap();
            }
            let batch = 5;
            let mut hs = vec![vec![0.0f32; 20]; batch];
            let mut cs = vec![vec![0.0f32; 20]; batch];
            let mut hb = vec![0.0f32; batch * 20];
            let mut cb = vec![0.0f32; batch * 20];
            let mut rng = Rng::new(37);
            for _ in 0..12 {
                let toks: Vec<usize> =
                    (0..batch).map(|_| rng.below_usize(30)).collect();
                for (s, &t) in toks.iter().enumerate() {
                    a.step_token(t, &mut hs[s], &mut cs[s]);
                }
                b.step_tokens(&toks, &mut hb, &mut cb);
                for s in 0..batch {
                    for k in 0..20 {
                        assert_eq!(hs[s][k].to_bits(), hb[s * 20 + k].to_bits(),
                                   "planes={planes} h[{s}][{k}]");
                        assert_eq!(cs[s][k].to_bits(), cb[s * 20 + k].to_bits(),
                                   "planes={planes} c[{s}][{k}]");
                    }
                }
            }
        }
    }

    #[test]
    fn cloned_cell_shares_planes_and_matches_bitwise() {
        let (mut a, _, _) = mk_cell(30, 16, 57);
        let mut b = a.clone();
        // the clone aliases the source's plane allocations...
        assert_eq!(a.wh.plane_ptr(), b.wh.plane_ptr());
        assert_eq!(a.wx.plane_ptr(), b.wx.plane_ptr());
        assert_eq!(a.wh.plane_owners(), 2);
        // ...and walks the identical op sequence on its own scratch
        let (mut ha, mut ca) = (vec![0.0f32; 16], vec![0.0f32; 16]);
        let (mut hb, mut cb) = (vec![0.0f32; 16], vec![0.0f32; 16]);
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let tok = rng.below_usize(30);
            a.step_token(tok, &mut ha, &mut ca);
            b.step_token(tok, &mut hb, &mut cb);
            for k in 0..16 {
                assert_eq!(ha[k].to_bits(), hb[k].to_bits());
                assert_eq!(ca[k].to_bits(), cb[k].to_bits());
            }
        }
        drop(b);
        assert_eq!(a.wh.plane_owners(), 1);
    }

    #[test]
    fn footprint_is_packed() {
        let (cell, _, _) = mk_cell(50, 32, 21);
        // ternary: 2 bits/weight (+ padding) vs 4 bytes dense
        let dense = (50 + 32) * 4 * 32 * 4;
        assert!(cell.weight_bytes() * 8 < dense, "{}", cell.weight_bytes());
    }
}
