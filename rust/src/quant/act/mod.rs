//! Low-bit **activation** datapath (the last f32 islands, quantized).
//!
//! The packed serving path stores weights at 1–2 bits, but until this
//! module every activation, gate tail, and the LM head ran in f32 — the
//! paper's "MACs become accumulations" regime never actually reached
//! the serving hot loop. `quant::act` closes that gap behind an explicit
//! per-backend knob ([`Datapath`], wired through
//! `BackendSpec::datapath` / `[serve] datapath` / `--datapath`):
//!
//! * [`Datapath::F32`] (default) — **bit-identical to the historical
//!   engine**: none of this module's code executes; every digest gate
//!   and equivalence test keeps its exact pre-datapath output. This is
//!   the escape hatch.
//! * [`Datapath::Lut8`] — the gate tail's tanh/sigmoid evaluate through
//!   shared 256-entry int8 lookup tables ([`lut`]) instead of `exp`;
//!   everything else (GEMMs, LM head) stays f32.
//! * [`Datapath::Xnor`] — the full low-bit path: int16 64K-entry gate
//!   LUTs, hidden states **binarized** per step ([`binarize`]) so the
//!   recurrent GEMM runs as pure xnor/popcount over the existing
//!   `Arc<[u64]>` weight bit planes (`quant::gemm::gemm_xnor`), and the
//!   LM head evaluated in int8 with per-row/per-column scales
//!   ([`head::QuantHead`]), including a fused top-k that never
//!   materializes the full f32 logit row.
//!
//! Rounding rules are documented at each table ([`lut`]) and quantizer
//! ([`head`]); property tests bound the LUT tails' max-abs error vs the
//! f32 tails and pin the xnor accumulator bit-for-bit against a dense
//! ±1 integer reference.

pub mod binarize;
pub mod head;
pub mod lut;
pub mod tail;

pub use binarize::BinarizedBatch;
pub use head::QuantHead;

use anyhow::{bail, Result};

/// Which activation datapath a packed backend runs (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Datapath {
    /// Full-precision activations — bit-identical to the pre-datapath
    /// engine (the escape hatch; default).
    F32,
    /// int8 256-entry tanh/sigmoid LUT gate tail; GEMMs and head f32.
    Lut8,
    /// int16 LUT tails + binarized hidden state (xnor/popcount
    /// recurrent GEMM) + int8 LM head.
    Xnor,
}

impl Datapath {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Datapath::F32,
            "lut8" => Datapath::Lut8,
            "xnor" => Datapath::Xnor,
            other => bail!("unknown datapath '{other}' \
                            (accepted: f32, lut8, xnor)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Datapath::F32 => "f32",
            Datapath::Lut8 => "lut8",
            Datapath::Xnor => "xnor",
        }
    }

    pub fn all() -> [Datapath; 3] {
        [Datapath::F32, Datapath::Lut8, Datapath::Xnor]
    }
}

impl Default for Datapath {
    fn default() -> Self {
        Datapath::F32
    }
}

impl std::fmt::Display for Datapath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_error_lists_accepted() {
        for dp in Datapath::all() {
            assert_eq!(Datapath::parse(dp.label()).unwrap(), dp);
        }
        assert_eq!(Datapath::default(), Datapath::F32);
        let err = format!("{:#}", Datapath::parse("int4").unwrap_err());
        assert!(err.contains("f32") && err.contains("lut8")
                && err.contains("xnor"),
                "datapath parse error must list accepted values: {err}");
    }
}
