//! Per-step hidden-state binarization: the input side of the
//! xnor/popcount recurrent GEMM.
//!
//! Under [`Datapath::Xnor`](super::Datapath) each decode step packs the
//! active slots' h rows into sign bit-words: bit `r` of row `j` is set
//! iff `h[j][r] >= 0` (ties to +1 — `+0.0` and `-0.0` both compare
//! `>= 0`, so the rule is total and deterministic), with a per-row
//! scale `s_j = mean(|h[j]|)` restoring magnitude after the integer
//! dot product (the standard binary-activation estimator: `h ≈ s_j ·
//! sign(h)`). A freshly-zeroed state row binarizes to all-set bits but
//! `s_j = 0`, so its xnor GEMM contribution is exactly `0.0` — fresh
//! streams behave identically to the f32 path.
//!
//! The word layout matches the weight planes' column layout
//! (`words_per_col` words per row, bit `b` of word `w` covering
//! element `64*w + b`, padding bits zero), so the xnor kernel walks
//! both operands with the same indexing.

use crate::quant::pack::words_per_col;

/// Grow-only scratch holding one batch's binarized rows + scales.
#[derive(Default)]
pub struct BinarizedBatch {
    /// `(batch, words_per_col(rows))` row-major sign words.
    pub words: Vec<u64>,
    /// Per-row dequant scale `mean(|h|)`.
    pub scales: Vec<f32>,
    /// Elements per row (the GEMM contraction width).
    pub rows: usize,
}

impl BinarizedBatch {
    /// Pack `x` (row-major `(batch, rows)`) into sign words + scales.
    /// Reuses the allocations across steps; contents are overwritten.
    pub fn pack(&mut self, x: &[f32], batch: usize, rows: usize) {
        debug_assert_eq!(x.len(), batch * rows);
        let wpc = words_per_col(rows);
        self.rows = rows;
        self.words.clear();
        self.words.resize(batch * wpc, 0);
        self.scales.clear();
        self.scales.resize(batch, 0.0);
        for j in 0..batch {
            let row = &x[j * rows..(j + 1) * rows];
            let words = &mut self.words[j * wpc..(j + 1) * wpc];
            let mut abs_sum = 0.0f32;
            for (r, &v) in row.iter().enumerate() {
                abs_sum += v.abs();
                if v >= 0.0 {
                    words[r / 64] |= 1u64 << (r % 64);
                }
            }
            self.scales[j] = abs_sum / rows as f32;
        }
    }

    /// One row's sign words.
    pub fn row_words(&self, j: usize) -> &[u64] {
        let wpc = words_per_col(self.rows);
        &self.words[j * wpc..(j + 1) * wpc]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packs_signs_and_mean_abs_scale() {
        let mut b = BinarizedBatch::default();
        let x = [1.0f32, -2.0, 0.5, -0.25];
        b.pack(&x, 1, 4);
        assert_eq!(b.rows, 4);
        assert_eq!(b.row_words(0)[0], 0b0101);
        assert!((b.scales[0] - 3.75 / 4.0).abs() < 1e-7);
    }

    #[test]
    fn zero_row_scales_to_zero() {
        let mut b = BinarizedBatch::default();
        b.pack(&[0.0; 8], 1, 8);
        // sign(0) = +1 per the tie rule, but the scale is exactly 0
        assert_eq!(b.row_words(0)[0], 0xFF);
        assert_eq!(b.scales[0], 0.0);
    }

    #[test]
    fn padding_bits_stay_zero_and_scratch_is_reused() {
        let mut b = BinarizedBatch::default();
        b.pack(&vec![1.0; 2 * 70], 2, 70);
        for j in 0..2 {
            let w = b.row_words(j);
            assert_eq!(w.len(), 2);
            assert_eq!(w[1] >> 6, 0, "pad bits beyond row 70 must be 0");
        }
        // repack smaller: stale words must not leak through
        b.pack(&[-1.0, -1.0], 1, 2);
        assert_eq!(b.row_words(0)[0], 0);
        assert_eq!(b.scales.len(), 1);
    }
}
