//! Shared tanh/sigmoid lookup tables (int8: 256 entries, int16: 64K).
//!
//! This is the software twin of the accelerator's activation LUT ROM
//! (Ott et al. map where low-precision recurrent nonlinearities break;
//! the fix is a fixed, documented rounding rule applied consistently at
//! table build AND at lookup):
//!
//! **Rounding rule.** The input domain is clamped to `[-8, +8]` (both
//! tanh and sigmoid are flat to ~1e-6 beyond ±8). For an `N`-entry
//! table, entry `i` holds the function evaluated at the uniform grid
//! point `x_i = -8 + i * 16/(N-1)`, quantized to the signed integer
//! range by `round(f(x_i) * Q)` with `Q = 127` (int8) or `Q = 32767`
//! (int16) — `f32::round`, ties away from zero. A lookup maps `x` to
//! the **nearest** grid index `i = round((clamp(x) + 8) * (N-1)/16)`
//! (same tie rule) and dequantizes by `entry / Q`. Both the grid and
//! the integer quantizer are monotone, so the tables are monotone
//! non-decreasing — enforced by a property test, because a
//! non-monotone gate nonlinearity breaks recurrent stability in ways
//! plain max-abs-error bounds don't catch.
//!
//! Worst-case absolute error (bounded by grid spacing × max slope +
//! output quantization step): int8 ≤ ~0.036 for tanh (slope ≤ 1),
//! int16 ≤ ~1.4e-4 — both asserted with margin in
//! `rust/tests/quant_properties.rs`.
//!
//! Tables are built once per process behind `OnceLock` and shared by
//! every backend/shard (they are pure functions of the rule above, so
//! sharing cannot couple streams).

use std::sync::OnceLock;

/// Input clamp bound: tanh/sigmoid are saturated outside `[-8, 8]`.
pub const ACT_CLAMP: f32 = 8.0;

/// Entries in the int8 tables ([`Datapath::Lut8`](super::Datapath)).
pub const LUT8_ENTRIES: usize = 256;

/// Entries in the int16 tables ([`Datapath::Xnor`](super::Datapath)).
pub const LUT16_ENTRIES: usize = 1 << 16;

struct Tables8 {
    tanh: [i8; LUT8_ENTRIES],
    sig: [i8; LUT8_ENTRIES],
}

struct Tables16 {
    tanh: Vec<i16>,
    sig: Vec<i16>,
}

static T8: OnceLock<Tables8> = OnceLock::new();
static T16: OnceLock<Tables16> = OnceLock::new();

fn grid(i: usize, n: usize) -> f32 {
    -ACT_CLAMP + (i as f32) * (2.0 * ACT_CLAMP) / ((n - 1) as f32)
}

fn t8() -> &'static Tables8 {
    T8.get_or_init(|| {
        let mut tanh = [0i8; LUT8_ENTRIES];
        let mut sig = [0i8; LUT8_ENTRIES];
        for i in 0..LUT8_ENTRIES {
            let x = grid(i, LUT8_ENTRIES);
            tanh[i] = (x.tanh() * 127.0).round() as i8;
            sig[i] = (sigmoid_exact(x) * 127.0).round() as i8;
        }
        Tables8 { tanh, sig }
    })
}

fn t16() -> &'static Tables16 {
    T16.get_or_init(|| {
        let mut tanh = vec![0i16; LUT16_ENTRIES];
        let mut sig = vec![0i16; LUT16_ENTRIES];
        for i in 0..LUT16_ENTRIES {
            let x = grid(i, LUT16_ENTRIES);
            tanh[i] = (x.tanh() * 32767.0).round() as i16;
            sig[i] = (sigmoid_exact(x) * 32767.0).round() as i16;
        }
        Tables16 { tanh, sig }
    })
}

/// The exact sigmoid the f32 gate tails use (reference for the tables).
#[inline]
pub fn sigmoid_exact(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Nearest-grid-index lookup per the documented rounding rule.
#[inline]
fn index(x: f32, n: usize) -> usize {
    let t = (x.clamp(-ACT_CLAMP, ACT_CLAMP) + ACT_CLAMP)
        * ((n - 1) as f32) / (2.0 * ACT_CLAMP);
    // t ∈ [0, n-1]; round ties away from zero (all t ≥ 0 here)
    t.round() as usize
}

/// int8-table tanh (dequantized to f32).
#[inline]
pub fn tanh_lut8(x: f32) -> f32 {
    t8().tanh[index(x, LUT8_ENTRIES)] as f32 / 127.0
}

/// int8-table sigmoid (dequantized to f32).
#[inline]
pub fn sigmoid_lut8(x: f32) -> f32 {
    t8().sig[index(x, LUT8_ENTRIES)] as f32 / 127.0
}

/// int16-table tanh (dequantized to f32).
#[inline]
pub fn tanh_lut16(x: f32) -> f32 {
    t16().tanh[index(x, LUT16_ENTRIES)] as f32 / 32767.0
}

/// int16-table sigmoid (dequantized to f32).
#[inline]
pub fn sigmoid_lut16(x: f32) -> f32 {
    t16().sig[index(x, LUT16_ENTRIES)] as f32 / 32767.0
}

/// Raw table views for monotonicity/round-rule property tests.
pub fn tables_i8() -> (&'static [i8], &'static [i8]) {
    let t = t8();
    (&t.tanh, &t.sig)
}

/// Raw table views for monotonicity/round-rule property tests.
pub fn tables_i16() -> (&'static [i16], &'static [i16]) {
    let t = t16();
    (&t.tanh, &t.sig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_hits_documented_grid() {
        // entry 0 is f(-8), the last entry f(+8), the midpoint f(0)
        assert_eq!(tanh_lut8(-100.0), -1.0);
        assert_eq!(tanh_lut8(100.0), 1.0);
        assert_eq!(tanh_lut16(0.0), 0.0);
        assert!((sigmoid_lut16(0.0) - 0.5).abs() < 1e-4);
        assert!(sigmoid_lut8(-100.0).abs() < 1e-6);
    }

    #[test]
    fn error_vs_exact_is_bounded() {
        let mut worst8 = 0.0f32;
        let mut worst16 = 0.0f32;
        let mut x = -9.0f32;
        while x < 9.0 {
            worst8 = worst8
                .max((tanh_lut8(x) - x.tanh()).abs())
                .max((sigmoid_lut8(x) - sigmoid_exact(x)).abs());
            worst16 = worst16
                .max((tanh_lut16(x) - x.tanh()).abs())
                .max((sigmoid_lut16(x) - sigmoid_exact(x)).abs());
            x += 0.00313;
        }
        assert!(worst8 <= 0.05, "int8 act error {worst8}");
        assert!(worst16 <= 2.5e-4, "int16 act error {worst16}");
    }

    #[test]
    fn nan_input_is_contained() {
        // clamp(NaN) stays NaN; the usize cast lands on entry 0 — a
        // saturated value, never an out-of-bounds read
        assert!(tanh_lut8(f32::NAN).is_finite());
        assert!(sigmoid_lut16(f32::NAN).is_finite());
    }
}
