//! Datapath-selectable gate tails: the exact op sequence of the f32
//! LSTM/GRU tails (`quant::cell`), with the nonlinearities swapped for
//! the shared LUTs of [`super::lut`].
//!
//! The affine folded-BN part stays f32 on every datapath — the LUTs
//! replace only the transcendental evaluations, which is where the
//! accelerator's datapath differs from a CPU (an activation ROM read
//! vs an `exp` ladder). Keeping the fold bitwise-identical to the f32
//! tail means the per-datapath error is exactly the table error, which
//! the property tests can bound tightly.
//!
//! Rows are independent, so the engine shards these across pool
//! workers exactly like `RecurrentCell::gate_tail_rows`.

use super::lut::{sigmoid_exact, sigmoid_lut16, sigmoid_lut8, tanh_lut16,
                 tanh_lut8};
use super::Datapath;
use crate::quant::cell::{CellArch, GateParams};

#[inline]
fn acts(dp: Datapath) -> (fn(f32) -> f32, fn(f32) -> f32) {
    match dp {
        Datapath::F32 => (|x| x.tanh(), sigmoid_exact),
        Datapath::Lut8 => (tanh_lut8, sigmoid_lut8),
        Datapath::Xnor => (tanh_lut16, sigmoid_lut16),
    }
}

/// Datapath-selected gate tail over a row-major block of streams —
/// same contract as `RecurrentCell::gate_tail_rows` (`xw` consumed in
/// place, row count inferred from `xw.len()`), dispatched on `arch`.
pub fn gate_tail_rows_dp(dp: Datapath, arch: CellArch, p: &GateParams<'_>,
                         hid: usize, xw: &mut [f32], hw: &[f32],
                         state: &mut [f32]) {
    match arch {
        CellArch::Lstm => lstm_tail_rows(dp, p, hid, xw, hw, state),
        CellArch::Gru => gru_tail_rows(dp, p, hid, xw, hw, state),
    }
}

/// LSTM tail (state rows `[h | c]`, gate order `[i, f, g, o]`) with
/// the datapath's tanh/sigmoid. On [`Datapath::F32`] this walks the
/// identical op sequence as the cell's own f32 tail.
pub fn lstm_tail_rows(dp: Datapath, p: &GateParams<'_>, hid: usize,
                      xw: &mut [f32], hw: &[f32], state: &mut [f32]) {
    let (tanh_f, sig_f) = acts(dp);
    let n4 = 4 * hid;
    let sw = 2 * hid;
    debug_assert_eq!(xw.len() % n4, 0);
    let rows = xw.len() / n4;
    debug_assert_eq!(hw.len(), rows * n4);
    debug_assert_eq!(state.len(), rows * sw);
    for b in 0..rows {
        let xw = &mut xw[b * n4..(b + 1) * n4];
        let hw = &hw[b * n4..(b + 1) * n4];
        let (h, c) = state[b * sw..(b + 1) * sw].split_at_mut(hid);
        for j in 0..n4 {
            xw[j] = xw[j] * p.scale_x[j] + p.shift_x[j]
                + hw[j] * p.scale_h[j] + p.shift_h[j]
                + p.bias[j];
        }
        for k in 0..hid {
            let i = sig_f(xw[k]);
            let f = sig_f(xw[hid + k]);
            let g = tanh_f(xw[2 * hid + k]);
            let o = sig_f(xw[3 * hid + k]);
            c[k] = f * c[k] + i * g;
            h[k] = o * tanh_f(c[k]);
        }
    }
}

/// GRU tail (state rows `[h]`, gate order `[r, z, n]`, reset gate on
/// the recurrent candidate) with the datapath's tanh/sigmoid.
pub fn gru_tail_rows(dp: Datapath, p: &GateParams<'_>, hid: usize,
                     xw: &mut [f32], hw: &[f32], state: &mut [f32]) {
    let (tanh_f, sig_f) = acts(dp);
    let n3 = 3 * hid;
    debug_assert_eq!(xw.len() % n3, 0);
    let rows = xw.len() / n3;
    debug_assert_eq!(hw.len(), rows * n3);
    debug_assert_eq!(state.len(), rows * hid);
    for b in 0..rows {
        let xw = &mut xw[b * n3..(b + 1) * n3];
        let hw = &hw[b * n3..(b + 1) * n3];
        let h = &mut state[b * hid..(b + 1) * hid];
        for j in 0..n3 {
            xw[j] = xw[j] * p.scale_x[j] + p.shift_x[j] + p.bias[j];
        }
        for j in 0..2 * hid {
            xw[j] += hw[j] * p.scale_h[j] + p.shift_h[j];
        }
        for k in 0..hid {
            let r = sig_f(xw[k]);
            let z = sig_f(xw[hid + k]);
            let hn = hw[2 * hid + k] * p.scale_h[2 * hid + k]
                + p.shift_h[2 * hid + k];
            let n = tanh_f(xw[2 * hid + k] + r * hn);
            h[k] = (1.0 - z) * n + z * h[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn params(gw: usize, rng: &mut Rng)
        -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        (
            (0..gw).map(|_| 1.0 + 0.1 * rng.normal_f32()).collect(),
            (0..gw).map(|_| 0.05 * rng.normal_f32()).collect(),
            (0..gw).map(|_| 1.0 + 0.1 * rng.normal_f32()).collect(),
            (0..gw).map(|_| 0.05 * rng.normal_f32()).collect(),
            (0..gw).map(|_| 0.2 * rng.normal_f32()).collect(),
        )
    }

    #[test]
    fn lut_tails_track_f32_tail() {
        // one tail call: LUT output must sit within a small, datapath-
        // dependent band of the exact-f32 tail on the same inputs
        let mut rng = Rng::new(77);
        for arch in CellArch::all() {
            let hid = 24;
            let gw = arch.gates() * hid;
            let sw = if arch == CellArch::Lstm { 2 * hid } else { hid };
            let (sx, fx, sh, fh, b) = params(gw, &mut rng);
            let p = GateParams { scale_x: &sx, shift_x: &fx, scale_h: &sh,
                                 shift_h: &fh, bias: &b };
            let rows = 3;
            let xw0: Vec<f32> =
                (0..rows * gw).map(|_| rng.normal_f32()).collect();
            let hw: Vec<f32> =
                (0..rows * gw).map(|_| rng.normal_f32()).collect();
            let st0: Vec<f32> =
                (0..rows * sw).map(|_| 0.3 * rng.normal_f32()).collect();
            let run = |dp: Datapath| {
                let mut xw = xw0.clone();
                let mut st = st0.clone();
                gate_tail_rows_dp(dp, arch, &p, hid, &mut xw, &hw, &mut st);
                st
            };
            let exact = run(Datapath::F32);
            for (dp, bound) in [(Datapath::Lut8, 0.2f32),
                                (Datapath::Xnor, 1.5e-3)] {
                let got = run(dp);
                let worst = exact
                    .iter()
                    .zip(&got)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(worst <= bound,
                        "{arch} {dp}: tail error {worst} > {bound}");
            }
        }
    }
}
