//! int8 LM head with per-row/per-column scales and a fused top-k.
//!
//! **Quantization rule.** Weights are quantized per *column* (one
//! output token each): `s_c = max_r |w[r,c]| / 127`, `qw[r,c] =
//! round(w[r,c] / s_c)` clamped to `[-127, 127]` (`f32::round`, ties
//! away from zero; an all-zero column keeps `s_c = 0`). Activations
//! are quantized per *row* at consume time: `a_j = max_r |h[j,r]| /
//! 127`, same round/clamp. A logit is then the pure int32 dot product
//! dequantized once: `logit[j,c] = a_j · s_c · Σ_r qh[j,r]·qw[r,c] +
//! bias[c]` — the bias stays f32 (it is read once per logit, not per
//! MAC, so quantizing it buys nothing).
//!
//! The weight matrix is stored **column-major** (`qw[c*hidden + r]`)
//! so a column shard `[c0, c1)` streams a contiguous byte range —
//! the same locality contract as the packed-plane GEMM shards.
//!
//! **Fused top-k.** When only argmax/top-k is consumed, the
//! column-sharded pass keeps a running k-best list per shard instead
//! of writing `vocab` f32 logits ([`QuantHead::topk_cols`] /
//! [`QuantHead::topk`]): the full f32 logit row is never
//! materialized. Ordering is deterministic — descending logit, ties
//! broken toward the **lower** token index — so any shard split
//! merges to the same answer.

use crate::quant::simd::SharedOut;

/// Grow-only scratch holding one batch's int8-quantized h rows.
#[derive(Default)]
pub struct QuantizedRows {
    /// `(batch, width)` row-major int8 values.
    pub q: Vec<i8>,
    /// Per-row dequant scale `a_j`.
    pub scales: Vec<f32>,
    /// Elements per row.
    pub width: usize,
}

impl QuantizedRows {
    /// Quantize `h` (row-major `(batch, width)`) per the documented
    /// rule. Reuses allocations; contents are overwritten.
    pub fn pack(&mut self, h: &[f32], batch: usize, width: usize) {
        debug_assert_eq!(h.len(), batch * width);
        self.width = width;
        self.q.clear();
        self.q.resize(batch * width, 0);
        self.scales.clear();
        self.scales.resize(batch, 0.0);
        for j in 0..batch {
            let row = &h[j * width..(j + 1) * width];
            let amax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if amax == 0.0 {
                continue; // scale 0, all-zero q row
            }
            let a = amax / 127.0;
            self.scales[j] = a;
            let q = &mut self.q[j * width..(j + 1) * width];
            for (qv, &v) in q.iter_mut().zip(row) {
                *qv = (v / a).round().clamp(-127.0, 127.0) as i8;
            }
        }
    }

    /// One row's int8 values.
    pub fn row(&self, j: usize) -> &[i8] {
        &self.q[j * self.width..(j + 1) * self.width]
    }
}

/// The int8 LM head: column-quantized weights + f32 bias.
pub struct QuantHead {
    pub hidden: usize,
    pub vocab: usize,
    /// Column-major int8 weights: column `c` at `[c*hidden, (c+1)*hidden)`.
    qw: Vec<i8>,
    /// Per-column dequant scale `s_c`.
    col_scale: Vec<f32>,
    /// f32 bias (added after dequantization).
    bias: Vec<f32>,
}

impl QuantHead {
    /// Quantize a row-major `(hidden, vocab)` f32 head.
    pub fn new(head_w: &[f32], head_b: &[f32], hidden: usize, vocab: usize)
        -> Self {
        assert_eq!(head_w.len(), hidden * vocab);
        assert_eq!(head_b.len(), vocab);
        let mut qw = vec![0i8; hidden * vocab];
        let mut col_scale = vec![0.0f32; vocab];
        for c in 0..vocab {
            let mut amax = 0.0f32;
            for r in 0..hidden {
                amax = amax.max(head_w[r * vocab + c].abs());
            }
            if amax == 0.0 {
                continue;
            }
            let s = amax / 127.0;
            col_scale[c] = s;
            for r in 0..hidden {
                qw[c * hidden + r] = (head_w[r * vocab + c] / s)
                    .round()
                    .clamp(-127.0, 127.0) as i8;
            }
        }
        Self { hidden, vocab, qw, col_scale, bias: head_b.to_vec() }
    }

    /// Packed weight bytes (1 byte/weight + per-column scale + bias).
    pub fn bytes(&self) -> usize {
        self.qw.len() + (self.col_scale.len() + self.bias.len()) * 4
    }

    #[inline]
    fn logit(&self, qh: &[i8], a: f32, c: usize) -> f32 {
        let col = &self.qw[c * self.hidden..(c + 1) * self.hidden];
        let mut dot: i32 = 0;
        for (&q, &w) in qh.iter().zip(col) {
            dot += q as i32 * w as i32;
        }
        a * self.col_scale[c] * dot as f32 + self.bias[c]
    }

    /// Column shard `[c0, c1)` of the quantized logit pass, scattered
    /// into active slots' logit rows — the drop-in counterpart of
    /// `quant::gemm::gemm_f32_bias_cols` for the xnor datapath: `qh` is
    /// the quantized `(batch, hidden)` block ([`QuantizedRows`]),
    /// `row_of` maps block rows to output rows.
    ///
    /// # Safety
    /// `out` must view a live buffer of at least `(max(row_of)+1) *
    /// vocab` elements, and no concurrent shard may overlap this one's
    /// column range.
    pub unsafe fn logits_cols(&self, qh: &QuantizedRows, row_of: &[usize],
                              c0: usize, c1: usize, out: SharedOut) {
        debug_assert_eq!(qh.width, self.hidden);
        debug_assert!(c0 <= c1 && c1 <= self.vocab);
        for (j, &orow) in row_of.iter().enumerate() {
            let row = qh.row(j);
            let a = qh.scales[j];
            for c in c0..c1 {
                // SAFETY: forwarded from this function's contract.
                unsafe { out.write(orow * self.vocab + c,
                                   self.logit(row, a, c)) };
            }
        }
    }

    /// Shard-local fused top-k over columns `[c0, c1)`: appends this
    /// shard's k best `(token, logit)` candidates for one quantized h
    /// row to `cands` without materializing any full logit row. Merge
    /// shards with [`QuantHead::merge_topk`].
    pub fn topk_cols(&self, qh: &[i8], a: f32, c0: usize, c1: usize,
                     k: usize, cands: &mut Vec<(usize, f32)>) {
        let base = cands.len();
        for c in c0..c1 {
            let v = self.logit(qh, a, c);
            let local = &mut cands[base..];
            if local.len() < k {
                cands.push((c, v));
                continue;
            }
            // replace the shard's current worst if strictly better
            // (ties keep the earlier, lower-index candidate)
            let (wi, &(wc, wv)) = local
                .iter()
                .enumerate()
                .min_by(|(_, x), (_, y)| {
                    x.1.partial_cmp(&y.1)
                        .unwrap()
                        .then(y.0.cmp(&x.0)) // equal logits: higher idx is worse
                })
                .unwrap();
            if v > wv || (v == wv && c < wc) {
                local[wi] = (c, v);
            }
        }
    }

    /// Deterministic candidate merge: descending logit, ties toward the
    /// lower token index; truncates to `k`. Shard-split-invariant.
    pub fn merge_topk(cands: &mut Vec<(usize, f32)>, k: usize) {
        cands.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
        });
        cands.dedup_by_key(|e| e.0);
        cands.truncate(k);
    }

    /// Full fused top-k for one f32 h row (quantize + sharded candidate
    /// pass + merge), split across `shards` column ranges.
    pub fn topk(&self, h: &[f32], k: usize, shards: usize)
        -> Vec<(usize, f32)> {
        let mut rows = QuantizedRows::default();
        rows.pack(h, 1, self.hidden);
        let (qh, a) = (rows.row(0), rows.scales[0]);
        let mut cands = Vec::with_capacity(k * shards.max(1));
        let shards = shards.max(1).min(self.vocab.max(1));
        for si in 0..shards {
            let c0 = si * self.vocab / shards;
            let c1 = (si + 1) * self.vocab / shards;
            self.topk_cols(qh, a, c0, c1, k, &mut cands);
        }
        Self::merge_topk(&mut cands, k);
        cands
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mk_head(hidden: usize, vocab: usize, seed: u64)
        -> (QuantHead, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> =
            (0..hidden * vocab).map(|_| 0.3 * rng.normal_f32()).collect();
        let b: Vec<f32> = (0..vocab).map(|_| 0.1 * rng.normal_f32()).collect();
        (QuantHead::new(&w, &b, hidden, vocab), w, b)
    }

    fn full_logits(q: &QuantHead, h: &[f32]) -> Vec<f32> {
        let mut rows = QuantizedRows::default();
        rows.pack(h, 1, q.hidden);
        let mut y = vec![f32::NAN; q.vocab];
        {
            let out = SharedOut::new(&mut y);
            // SAFETY: one shard over all columns, buffer outlives it.
            unsafe { q.logits_cols(&rows, &[0], 0, q.vocab, out) };
        }
        y
    }

    #[test]
    fn quantized_logits_track_f32_head() {
        let mut rng = Rng::new(91);
        let (q, w, b) = mk_head(48, 60, 7);
        let h: Vec<f32> = (0..48).map(|_| rng.normal_f32()).collect();
        let got = full_logits(&q, &h);
        let mut worst = 0.0f32;
        let mut scale = 0.0f32;
        for c in 0..60 {
            let want: f32 =
                (0..48).map(|r| h[r] * w[r * 60 + c]).sum::<f32>() + b[c];
            worst = worst.max((got[c] - want).abs());
            scale = scale.max(want.abs());
        }
        // two int8 quantizers in series: ~1% relative is the budget
        assert!(worst <= 0.02 * scale.max(1.0),
                "head error {worst} (scale {scale})");
    }

    #[test]
    fn column_shards_reassemble_bitwise() {
        let mut rng = Rng::new(93);
        let (q, _, _) = mk_head(32, 41, 9);
        let h: Vec<f32> = (0..2 * 32).map(|_| rng.normal_f32()).collect();
        let mut rows = QuantizedRows::default();
        rows.pack(&h, 2, 32);
        let run = |splits: &[usize]| {
            let mut y = vec![f32::NAN; 2 * 41];
            {
                let out = SharedOut::new(&mut y);
                for p in splits.windows(2) {
                    // SAFETY: disjoint shards, buffer outlives them.
                    unsafe { q.logits_cols(&rows, &[0, 1], p[0], p[1], out) };
                }
            }
            y
        };
        let whole = run(&[0, 41]);
        for splits in [vec![0, 1, 41], vec![0, 13, 27, 41]] {
            let sharded = run(&splits);
            for (i, (a, b)) in whole.iter().zip(&sharded).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{splits:?} elt {i}");
            }
        }
    }

    #[test]
    fn fused_topk_matches_full_argsort_for_every_shard_split() {
        let mut rng = Rng::new(95);
        let (q, _, _) = mk_head(40, 73, 11);
        for trial in 0..10 {
            let h: Vec<f32> = (0..40).map(|_| rng.normal_f32()).collect();
            let logits = full_logits(&q, &h);
            let mut order: Vec<usize> = (0..73).collect();
            order.sort_by(|&a, &b| {
                logits[b].partial_cmp(&logits[a]).unwrap().then(a.cmp(&b))
            });
            for k in [1usize, 5] {
                let want: Vec<usize> = order[..k].to_vec();
                for shards in [1usize, 2, 5, 73] {
                    let got: Vec<usize> = q
                        .topk(&h, k, shards)
                        .into_iter()
                        .map(|(c, _)| c)
                        .collect();
                    assert_eq!(got, want,
                               "trial {trial} k {k} shards {shards}");
                }
            }
        }
    }

    #[test]
    fn zero_h_and_zero_column_are_exact() {
        let mut w = vec![0.5f32; 8 * 5];
        for c in 0..5 {
            // column 2 all-zero
            if c == 2 {
                for r in 0..8 {
                    w[r * 5 + c] = 0.0;
                }
            }
        }
        let b = vec![1.0f32, -1.0, 0.25, 0.0, 2.0];
        let q = QuantHead::new(&w, &b, 8, 5);
        assert!(q.bytes() >= 8 * 5);
        let logits = full_logits(&q, &[0.0; 8]);
        // zero h: every logit collapses to the exact f32 bias
        for c in 0..5 {
            assert_eq!(logits[c].to_bits(), b[c].to_bits());
        }
        // zero column: exact bias regardless of h
        let logits = full_logits(&q, &[1.0; 8]);
        assert_eq!(logits[2].to_bits(), 0.25f32.to_bits());
    }
}
