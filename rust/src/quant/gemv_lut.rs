//! LUT-accelerated multiplier-free GEMV (the §Perf-optimized hot path).
//!
//! The naive kernels in [`super::gemv`] visit one set bit at a time
//! (`trailing_zeros` + scalar add), which costs ~1 dependent add per
//! nonzero weight — slower than a vectorized dense f32 GEMV despite the
//! 16x smaller weight stream. The classical fix (the same trick the
//! paper's mux-array plays in silicon, lifted to SW): process input rows
//! in groups of 8 and precompute the **subset-sum table**
//!
//! ```text
//! S_g[p] = Σ_{i: bit i of p set} x[8g + i]        (256 entries)
//! ```
//!
//! with one add per entry (S[p] = S[p & (p-1)] + x[lsb]). A column then
//! consumes a whole 8-row group with ONE table lookup + add:
//!
//! ```text
//! binary:  y[c] += 2*S_g[sign_byte] - group_total
//! ternary: y[c] += S_g[pos_byte] - S_g[neg_byte]
//! ```
//!
//! i.e. 1-2 adds per 8 weights instead of ~8, while streaming the packed
//! planes exactly once. The group loop is outermost so each 1 KB table
//! stays L1-hot across all columns.

use super::pack::{words_per_col, PackedBinary, PackedTernary};

/// Reusable scratch for the subset-sum tables (avoids per-call allocs in
/// the serving hot loop).
#[derive(Default)]
pub struct LutScratch {
    pub(crate) table: Vec<f32>,
}

#[inline]
pub(crate) fn build_subset_sums(x: &[f32], base: usize, out: &mut [f32]) {
    // out[p] = sum of x[base + i] over set bits i of p; x padded with 0.
    out[0] = 0.0;
    let get = |i: usize| -> f32 {
        if base + i < x.len() {
            x[base + i]
        } else {
            0.0
        }
    };
    for p in 1..256usize {
        let lsb = p.trailing_zeros() as usize;
        out[p] = out[p & (p - 1)] + get(lsb);
    }
}

/// LUT binary GEMV: y = xᵀW for a packed ±alpha matrix.
pub fn gemv_binary_lut(w: &PackedBinary, x: &[f32], y: &mut [f32],
                       scratch: &mut LutScratch) {
    assert_eq!(x.len(), w.rows);
    assert_eq!(y.len(), w.cols);
    let wpc = words_per_col(w.rows);
    let groups = w.rows.div_ceil(8);
    let total: f32 = x.iter().sum();
    // padding rows in the last group read sign bit 0 => contribute -alpha
    // * x_pad with x_pad = 0, handled by zero-padding in the table.
    for c in y.iter_mut() {
        *c = -total; // start from "all bits clear" = -sum(x)
    }
    scratch.table.resize(256, 0.0);
    let sign_bytes: &[u8] = le_bytes(&w.sign);
    for g in 0..groups {
        build_subset_sums(x, g * 8, &mut scratch.table);
        let t = &scratch.table;
        // byte g of column c lives at c*wpc*8 + g (little-endian words)
        for (c, yc) in y.iter_mut().enumerate() {
            let b = sign_bytes[c * wpc * 8 + g];
            *yc += 2.0 * t[b as usize];
        }
    }
    for c in y.iter_mut() {
        *c *= w.alpha;
    }
}

/// LUT ternary GEMV: y = xᵀW for a packed {-alpha, 0, +alpha} matrix.
pub fn gemv_ternary_lut(w: &PackedTernary, x: &[f32], y: &mut [f32],
                        scratch: &mut LutScratch) {
    assert_eq!(x.len(), w.rows);
    assert_eq!(y.len(), w.cols);
    let wpc = words_per_col(w.rows);
    let groups = w.rows.div_ceil(8);
    y.fill(0.0);
    scratch.table.resize(256, 0.0);
    let sign_bytes: &[u8] = le_bytes(&w.sign);
    let mask_bytes: &[u8] = le_bytes(&w.mask);
    for g in 0..groups {
        build_subset_sums(x, g * 8, &mut scratch.table);
        let t = &scratch.table;
        for (c, yc) in y.iter_mut().enumerate() {
            let idx = c * wpc * 8 + g;
            let m = mask_bytes[idx];
            let s = sign_bytes[idx];
            let pos = m & s;
            let neg = m & !s;
            *yc += t[pos as usize] - t[neg as usize];
        }
    }
    for c in y.iter_mut() {
        *c *= w.alpha;
    }
}

/// View a u64 slice as little-endian bytes (safe on all supported
/// targets; this crate only builds for little-endian CPUs, asserted
/// below). Shared by the per-slot LUT kernels here, the plane GEMV in
/// [`super::planes`], and the batched GEMM kernels in [`super::gemm`].
pub(crate) fn le_bytes(words: &[u64]) -> &[u8] {
    #[cfg(target_endian = "big")]
    compile_error!("packed-plane byte views assume little-endian");
    unsafe {
        std::slice::from_raw_parts(words.as_ptr() as *const u8, words.len() * 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gemv::{gemv_binary, gemv_f32, gemv_ternary};
    use crate::util::Rng;

    #[test]
    fn binary_lut_matches_naive_and_dense() {
        let mut rng = Rng::new(31);
        for (rows, cols) in [(64, 16), (100, 37), (129, 8), (1000, 40), (7, 3)] {
            let alpha = 0.2f32;
            let w: Vec<f32> = (0..rows * cols)
                .map(|_| if rng.bernoulli(0.5) { alpha } else { -alpha })
                .collect();
            let x: Vec<f32> = (0..rows).map(|_| rng.normal_f32()).collect();
            let packed = PackedBinary::pack(&w, rows, cols, alpha);
            let mut y0 = vec![0.0; cols];
            let mut y1 = vec![0.0; cols];
            let mut y2 = vec![0.0; cols];
            gemv_f32(&w, rows, cols, &x, &mut y0);
            gemv_binary(&packed, &x, &mut y1);
            let mut s = LutScratch::default();
            gemv_binary_lut(&packed, &x, &mut y2, &mut s);
            for c in 0..cols {
                assert!((y0[c] - y2[c]).abs() < 1e-3 * (1.0 + y0[c].abs()),
                        "({rows},{cols}) col {c}: dense {} lut {}", y0[c], y2[c]);
                assert!((y1[c] - y2[c]).abs() < 1e-3 * (1.0 + y1[c].abs()));
            }
        }
    }

    #[test]
    fn ternary_lut_matches_naive_and_dense() {
        let mut rng = Rng::new(33);
        for (rows, cols) in [(64, 16), (100, 37), (129, 8), (513, 24), (3, 2)] {
            let alpha = 0.15f32;
            let w: Vec<f32> = (0..rows * cols)
                .map(|_| [0.0, alpha, -alpha][rng.below_usize(3)])
                .collect();
            let x: Vec<f32> = (0..rows).map(|_| rng.normal_f32()).collect();
            let packed = PackedTernary::pack(&w, rows, cols, alpha);
            let mut y0 = vec![0.0; cols];
            let mut y2 = vec![0.0; cols];
            gemv_f32(&w, rows, cols, &x, &mut y0);
            let mut s = LutScratch::default();
            gemv_ternary_lut(&packed, &x, &mut y2, &mut s);
            let mut y1 = vec![0.0; cols];
            gemv_ternary(&packed, &x, &mut y1);
            for c in 0..cols {
                assert!((y0[c] - y2[c]).abs() < 1e-3 * (1.0 + y0[c].abs()),
                        "({rows},{cols}) col {c}: dense {} lut {}", y0[c], y2[c]);
                assert!((y1[c] - y2[c]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn padding_in_last_group_is_zero() {
        // rows=5: 3 padding bits in the byte; padded x reads as 0.
        let alpha = 1.0f32;
        let w = vec![alpha; 5 * 2];
        let packed = PackedBinary::pack(&w, 5, 2, alpha);
        let x = vec![1.0f32; 5];
        let mut y = vec![0.0; 2];
        let mut s = LutScratch::default();
        gemv_binary_lut(&packed, &x, &mut y, &mut s);
        assert!((y[0] - 5.0).abs() < 1e-4, "{y:?}");
    }
}
