//! Batched multiplier-free GEMM: one weight stream per step, all decode
//! slots — the software twin of the paper's §6 accelerator datapath,
//! where each 1–2-bit weight plane is streamed from DRAM **once** per
//! timestep and fans out to a whole array of accumulators.
//!
//! The per-slot LUT GEMV ([`super::gemv_lut`]) re-streams the packed
//! planes once per decode slot, so serving-batch weight traffic grows
//! linearly with slots. These kernels compute `Y = X·W` for an
//! `(batch, rows)` activation block and read each plane byte exactly
//! once, updating every slot's accumulator from it:
//!
//! * subset-sum tables are built **transposed** `(256, batch)` so that
//!   for a fixed table index `p` the `batch` values are contiguous;
//! * the accumulator block is kept column-major `(cols, batch)` during
//!   accumulation, making the per-column update
//!   `acc[c][0..batch] += T[pos] - T[neg]` a pair of contiguous
//!   vectorizable slice ops instead of `batch` scattered scalar walks;
//! * the final alpha fold transposes back into the row-major
//!   `(batch, cols)` output the cell consumes.
//!
//! **Bit-exactness contract:** every kernel here performs, per output
//! element, the *identical* sequence of f32 operations as its per-slot
//! counterpart (`gemv_binary_lut` / `gemv_ternary_lut` /
//! `gemv_ternary_planes`): same subset-sum recurrence, same group order,
//! same `t[pos] - t[neg]` (or `2·t[sign] − Σx`) accumulation, same final
//! alpha multiply. Batched serving therefore produces logits that match
//! the per-slot reference path bit for bit — enforced by
//! `rust/tests/quant_properties.rs`.

use super::gemv_lut::le_bytes;
use super::pack::{words_per_col, PackedBinary, PackedTernary};
use super::planes::TernaryPlanes;

/// Reusable scratch for the batched kernels (the serving hot loop
/// allocates nothing after the first step at a given width).
#[derive(Default)]
pub struct GemmScratch {
    /// Transposed subset-sum tables `(256, batch)`: `tables[p*batch + b]`.
    tables: Vec<f32>,
    /// One group's activation tile, transposed `(8, batch)`.
    xt: Vec<f32>,
    /// Column-major accumulator `(cols, batch)`.
    acc: Vec<f32>,
    /// Per-row activation sums (binary kernel only).
    totals: Vec<f32>,
}

impl GemmScratch {
    fn resize(&mut self, batch: usize, cols: usize) {
        self.tables.resize(256 * batch, 0.0);
        self.xt.resize(8 * batch, 0.0);
        self.acc.resize(cols * batch, 0.0);
        self.totals.resize(batch, 0.0);
    }
}

/// Transpose group `g`'s 8 input rows of the `(batch, rows)` block into
/// an `(8, batch)` tile, zero-padding rows past `rows` (identical to the
/// zero-padding the per-slot table build applies).
fn gather_tile(x: &[f32], rows: usize, batch: usize, g: usize, xt: &mut [f32]) {
    for i in 0..8 {
        let r = g * 8 + i;
        let row = &mut xt[i * batch..(i + 1) * batch];
        if r < rows {
            for (b, v) in row.iter_mut().enumerate() {
                *v = x[b * rows + r];
            }
        } else {
            row.fill(0.0);
        }
    }
}

/// Fold the column-major accumulator back into the row-major `(batch,
/// cols)` output with the trailing alpha multiply — the one epilogue all
/// three kernels share, kept in one place so the bit-exactness contract
/// can't drift between layouts.
fn fold_out(acc: &[f32], cols: usize, batch: usize, alpha: f32,
            y: &mut [f32]) {
    for c in 0..cols {
        for b in 0..batch {
            y[b * cols + c] = acc[c * batch + b] * alpha;
        }
    }
}

/// Batched subset-sum tables over a transposed `(8, batch)` tile:
/// `tables[p*batch + b] = Σ_{i: bit i of p} xt[i*batch + b]`, built with
/// the same `S[p] = S[p & (p-1)] + x[lsb]` recurrence as the scalar
/// [`super::gemv_lut::build_subset_sums`] — so every entry is bitwise
/// identical to the per-slot table for that slot's input.
fn build_subset_sums_batch(xt: &[f32], batch: usize, tables: &mut [f32]) {
    tables[..batch].fill(0.0);
    for p in 1..256usize {
        let lsb = p.trailing_zeros() as usize;
        let q = p & (p - 1);
        for b in 0..batch {
            tables[p * batch + b] = tables[q * batch + b] + xt[lsb * batch + b];
        }
    }
}

/// Batched LUT binary GEMM: `Y = X·W` for a packed ±alpha matrix,
/// `X` row-major `(batch, rows)`, `Y` row-major `(batch, cols)`.
/// Streams each sign-plane byte once for all `batch` rows; per-row math
/// is bit-identical to [`super::gemv_lut::gemv_binary_lut`].
pub fn gemm_binary_lut(w: &PackedBinary, x: &[f32], batch: usize,
                       y: &mut [f32], scratch: &mut GemmScratch) {
    assert_eq!(x.len(), batch * w.rows);
    assert_eq!(y.len(), batch * w.cols);
    if batch == 0 {
        return;
    }
    let wpc = words_per_col(w.rows);
    let groups = w.rows.div_ceil(8);
    let stride = wpc * 8;
    scratch.resize(batch, w.cols);
    // per-row prefix sum, same summation order as the per-slot kernel
    for b in 0..batch {
        scratch.totals[b] = x[b * w.rows..(b + 1) * w.rows].iter().sum();
    }
    for c in 0..w.cols {
        for b in 0..batch {
            scratch.acc[c * batch + b] = -scratch.totals[b];
        }
    }
    let sign = le_bytes(&w.sign);
    for g in 0..groups {
        gather_tile(x, w.rows, batch, g, &mut scratch.xt);
        build_subset_sums_batch(&scratch.xt, batch, &mut scratch.tables);
        let t = &scratch.tables;
        for c in 0..w.cols {
            let ts = &t[sign[c * stride + g] as usize * batch..][..batch];
            let a = &mut scratch.acc[c * batch..(c + 1) * batch];
            for b in 0..batch {
                a[b] += 2.0 * ts[b];
            }
        }
    }
    fold_out(&scratch.acc, w.cols, batch, w.alpha, y);
}

/// Batched LUT ternary GEMM over the sign/mask packing; per-row math is
/// bit-identical to [`super::gemv_lut::gemv_ternary_lut`].
pub fn gemm_ternary_lut(w: &PackedTernary, x: &[f32], batch: usize,
                        y: &mut [f32], scratch: &mut GemmScratch) {
    assert_eq!(x.len(), batch * w.rows);
    assert_eq!(y.len(), batch * w.cols);
    if batch == 0 {
        return;
    }
    let wpc = words_per_col(w.rows);
    let groups = w.rows.div_ceil(8);
    let stride = wpc * 8;
    scratch.resize(batch, w.cols);
    scratch.acc[..w.cols * batch].fill(0.0);
    let sign = le_bytes(&w.sign);
    let mask = le_bytes(&w.mask);
    for g in 0..groups {
        gather_tile(x, w.rows, batch, g, &mut scratch.xt);
        build_subset_sums_batch(&scratch.xt, batch, &mut scratch.tables);
        let t = &scratch.tables;
        for c in 0..w.cols {
            let idx = c * stride + g;
            let (m, s) = (mask[idx], sign[idx]);
            let tp = &t[(m & s) as usize * batch..][..batch];
            let tn = &t[(m & !s) as usize * batch..][..batch];
            let a = &mut scratch.acc[c * batch..(c + 1) * batch];
            for b in 0..batch {
                a[b] += tp[b] - tn[b];
            }
        }
    }
    fold_out(&scratch.acc, w.cols, batch, w.alpha, y);
}

/// Batched GEMM over precomputed pos/neg selector planes — the
/// wide-batch layout of [`super::planes`], and the closest software
/// analogue of the accelerator: two selector-plane bytes are read per
/// (group, column) **for the whole batch**, with no byte-ops in the
/// loop. Per-row math is bit-identical to
/// [`super::planes::gemv_ternary_planes`].
pub fn gemm_ternary_planes(w: &TernaryPlanes, x: &[f32], batch: usize,
                           y: &mut [f32], scratch: &mut GemmScratch) {
    assert_eq!(x.len(), batch * w.rows);
    assert_eq!(y.len(), batch * w.cols);
    if batch == 0 {
        return;
    }
    let wpc = words_per_col(w.rows);
    let groups = w.rows.div_ceil(8);
    let stride = wpc * 8;
    scratch.resize(batch, w.cols);
    scratch.acc[..w.cols * batch].fill(0.0);
    let pos = le_bytes(&w.pos);
    let neg = le_bytes(&w.neg);
    for g in 0..groups {
        gather_tile(x, w.rows, batch, g, &mut scratch.xt);
        build_subset_sums_batch(&scratch.xt, batch, &mut scratch.tables);
        let t = &scratch.tables;
        for c in 0..w.cols {
            let idx = c * stride + g;
            let tp = &t[pos[idx] as usize * batch..][..batch];
            let tn = &t[neg[idx] as usize * batch..][..batch];
            let a = &mut scratch.acc[c * batch..(c + 1) * batch];
            for b in 0..batch {
                a[b] += tp[b] - tn[b];
            }
        }
    }
    fold_out(&scratch.acc, w.cols, batch, w.alpha, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gemv_lut::{gemv_binary_lut, gemv_ternary_lut, LutScratch};
    use crate::quant::planes::gemv_ternary_planes;
    use crate::util::Rng;

    fn rand_ternary(rng: &mut Rng, n: usize, alpha: f32) -> Vec<f32> {
        (0..n).map(|_| [0.0, alpha, -alpha][rng.below_usize(3)]).collect()
    }

    #[test]
    fn batched_binary_matches_per_slot_bitwise() {
        let mut rng = Rng::new(51);
        for (rows, cols, batch) in [(64, 16, 4), (100, 37, 1), (7, 3, 5),
                                    (129, 8, 16), (65, 12, 3)] {
            let alpha = 0.2f32;
            let w: Vec<f32> = (0..rows * cols)
                .map(|_| if rng.bernoulli(0.5) { alpha } else { -alpha })
                .collect();
            let packed = PackedBinary::pack(&w, rows, cols, alpha);
            let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0f32; batch * cols];
            let mut s = GemmScratch::default();
            gemm_binary_lut(&packed, &x, batch, &mut y, &mut s);
            let mut ls = LutScratch::default();
            for b in 0..batch {
                let mut yb = vec![0.0f32; cols];
                gemv_binary_lut(&packed, &x[b * rows..(b + 1) * rows], &mut yb,
                                &mut ls);
                for c in 0..cols {
                    assert_eq!(y[b * cols + c].to_bits(), yb[c].to_bits(),
                               "({rows},{cols}) b {b} col {c}");
                }
            }
        }
    }

    #[test]
    fn batched_ternary_matches_per_slot_bitwise() {
        let mut rng = Rng::new(53);
        for (rows, cols, batch) in [(64, 16, 4), (100, 37, 2), (5, 2, 7),
                                    (513, 24, 8)] {
            let alpha = 0.15f32;
            let w = rand_ternary(&mut rng, rows * cols, alpha);
            let packed = PackedTernary::pack(&w, rows, cols, alpha);
            let planes = TernaryPlanes::from_packed(&packed);
            let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal_f32()).collect();
            let mut y_lut = vec![0.0f32; batch * cols];
            let mut y_pl = vec![0.0f32; batch * cols];
            let mut s = GemmScratch::default();
            gemm_ternary_lut(&packed, &x, batch, &mut y_lut, &mut s);
            gemm_ternary_planes(&planes, &x, batch, &mut y_pl, &mut s);
            let mut ls = LutScratch::default();
            for b in 0..batch {
                let xb = &x[b * rows..(b + 1) * rows];
                let mut y1 = vec![0.0f32; cols];
                gemv_ternary_lut(&packed, xb, &mut y1, &mut ls);
                let mut y2 = vec![0.0f32; cols];
                gemv_ternary_planes(&planes, xb, &mut y2, &mut ls);
                for c in 0..cols {
                    assert_eq!(y_lut[b * cols + c].to_bits(), y1[c].to_bits(),
                               "lut ({rows},{cols}) b {b} col {c}");
                    assert_eq!(y_pl[b * cols + c].to_bits(), y2[c].to_bits(),
                               "planes ({rows},{cols}) b {b} col {c}");
                }
            }
        }
    }

    #[test]
    fn zero_batch_is_a_noop() {
        let w = PackedTernary::pack(&[1.0f32, -1.0, 0.0, 1.0], 4, 1, 1.0);
        let planes = TernaryPlanes::from_packed(&w);
        let mut s = GemmScratch::default();
        let mut y: Vec<f32> = vec![];
        gemm_ternary_lut(&w, &[], 0, &mut y, &mut s);
        gemm_ternary_planes(&planes, &[], 0, &mut y, &mut s);
        let b = PackedBinary::pack(&[1.0f32, -1.0, 1.0, 1.0], 4, 1, 1.0);
        gemm_binary_lut(&b, &[], 0, &mut y, &mut s);
    }
}
