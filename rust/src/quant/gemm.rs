//! SIMD-tiled, batch-blocked multiplier-free GEMM: one weight stream per
//! step, all decode slots — the software twin of the paper's §6
//! accelerator datapath, where each 1–2-bit weight plane is streamed
//! from DRAM **once** per timestep and fans out to a whole array of
//! accumulators.
//!
//! The per-slot LUT GEMV ([`super::gemv_lut`]) re-streams the packed
//! planes once per decode slot, so serving-batch weight traffic grows
//! linearly with slots. These kernels compute `Y = X·W` for an
//! `(batch, rows)` activation block and read each plane byte once per
//! **lane tile**, updating 8 slots' accumulators from it:
//!
//! ## Tile layout
//!
//! * the batch dimension is blocked into **lane tiles of 8** rows
//!   ([`F32x8`]); a non-multiple-of-8 batch ends in a *masked tail
//!   tile* whose dead lanes carry zero activations and are simply never
//!   folded into the output;
//! * subset-sum tables are built **lane-major**: `tables[p]` is one
//!   `F32x8` holding index `p`'s subset sum for all 8 lanes, built with
//!   255 8-wide vector adds via the same `S[p] = S[p & (p-1)] + x[lsb]`
//!   recurrence as the scalar [`super::gemv_lut::build_subset_sums`];
//! * the accumulator is one `F32x8` per output column, so the
//!   per-(group, column) update `acc[c] += T[pos] - T[neg]` is two
//!   8-wide vector ops — no dynamic-length inner loop at any batch
//!   size;
//! * the fold-out epilogue multiplies by alpha lane-wise and scatters
//!   only the **live** lanes into the row-major `(batch, cols)` output.
//!
//! ## Column sharding
//!
//! Every kernel also comes as a `*_cols` variant computing only columns
//! `[c0, c1)` and writing through a [`SharedOut`] handle. Shards of
//! disjoint column ranges may run concurrently (the engine's thread
//! pool does exactly that — see `crate::engine::pool`): each shard
//! streams only **its own columns'** packed plane bytes, so plane
//! traffic stays one pass per shard, and since a column's math never
//! depends on which shard computes it, results are bit-identical for
//! every shard split and thread count.
//!
//! **Bit-exactness contract:** every kernel here performs, per output
//! element, the *identical* sequence of f32 operations as its per-slot
//! counterpart (`gemv_binary_lut` / `gemv_ternary_lut` /
//! `gemv_ternary_planes`): same subset-sum recurrence, same group order,
//! same `t[pos] - t[neg]` (or `2·t[sign] − Σx`) accumulation, same final
//! alpha multiply — each applied lane-wise ([`F32x8`] ops are pure
//! lane-wise IEEE f32). Batched serving therefore produces logits that
//! match the per-slot reference path bit for bit — enforced by
//! `rust/tests/quant_properties.rs` across batches {1, 7, 8, 9, 64}.

use super::act::BinarizedBatch;
use super::cell::Packed;
use super::gemv_lut::le_bytes;
use super::pack::{words_per_col, PackedBinary, PackedTernary};
use super::planes::TernaryPlanes;
use super::simd::{F32x8, SharedOut, LANES};

/// Reusable scratch for the batched kernels (the serving hot loop
/// allocates nothing after the first step at a given width).
///
/// All buffers are **grow-only**: stepping a smaller batch (or a
/// narrower column shard) after a larger one never shrinks a buffer, so
/// alternating batch sizes — the normal shape of continuous-batching
/// load — cannot trigger shrink-then-regrow reallocation churn. The
/// `scratch_capacity_is_stable_across_alternating_batches` test pins
/// this down.
#[derive(Default)]
pub struct GemmScratch {
    /// Lane-major subset-sum tables: 256 `F32x8` entries, rebuilt per
    /// (lane tile, 8-row group).
    tables: Vec<F32x8>,
    /// One group's activation tile, lane-major `(8 rows, 8 lanes)`.
    xt: Vec<F32x8>,
    /// One `F32x8` accumulator per sharded output column.
    acc: Vec<F32x8>,
    /// Per-batch-row activation sums (binary kernel only).
    totals: Vec<f32>,
    /// int32 popcount accumulators (xnor kernel only).
    xnor: Vec<i32>,
}

impl GemmScratch {
    /// Grow (never shrink) to serve `ncols` sharded columns at `batch`.
    fn ensure(&mut self, ncols: usize, batch: usize) {
        if self.tables.len() < 256 {
            self.tables.resize(256, F32x8::ZERO);
        }
        if self.xt.len() < LANES {
            self.xt.resize(LANES, F32x8::ZERO);
        }
        if self.acc.len() < ncols {
            self.acc.resize(ncols, F32x8::ZERO);
        }
        if self.totals.len() < batch {
            self.totals.resize(batch, 0.0);
        }
    }
}

/// Transpose group `g`'s 8 input rows × the tile's batch rows of the
/// row-major `(batch, rows)` block into a lane-major `(8, 8)` tile.
/// Matrix rows past `rows` and lanes past the live batch read 0 — the
/// masked tail tile; zero-padding matches what the per-slot table build
/// applies to the last row group.
fn gather_tile(x: &[f32], rows: usize, b0: usize, lanes: usize, g: usize,
               xt: &mut [F32x8]) {
    for i in 0..LANES {
        let r = g * LANES + i;
        let mut t = [0.0f32; LANES];
        if r < rows {
            for (l, v) in t[..lanes].iter_mut().enumerate() {
                *v = x[(b0 + l) * rows + r];
            }
        }
        xt[i] = F32x8(t);
    }
}

/// Lane-major subset-sum tables over one `(8, 8)` tile:
/// `tables[p].lane(l) = Σ_{i: bit i of p} xt[i].lane(l)`, built with the
/// same `S[p] = S[p & (p-1)] + x[lsb]` recurrence as the scalar
/// [`super::gemv_lut::build_subset_sums`] — so every lane's entry is
/// bitwise identical to the per-slot table for that slot's input.
fn build_subset_sums_tile(xt: &[F32x8], tables: &mut [F32x8]) {
    tables[0] = F32x8::ZERO;
    for p in 1..256usize {
        let lsb = p.trailing_zeros() as usize;
        tables[p] = tables[p & (p - 1)] + xt[lsb];
    }
}

/// Fold one lane tile's accumulators into the row-major `(batch, cols)`
/// output with the trailing alpha multiply — the one epilogue all three
/// kernels share, kept in one place so the bit-exactness contract can't
/// drift between layouts. Only the `lanes` live lanes are written; dead
/// tail lanes (and idle columns outside `[c0, c0+acc.len())`) are never
/// touched.
///
/// # Safety
/// The caller owns columns `[c0, c0 + acc.len())` of `out`, which views
/// a live row-major `(batch, cols)` buffer with `b0 + lanes <= batch`.
#[inline]
unsafe fn fold_tile(acc: &[F32x8], alpha: F32x8, b0: usize, lanes: usize,
                    c0: usize, cols: usize, out: SharedOut) {
    for (ci, a) in acc.iter().enumerate() {
        let v = *a * alpha;
        for l in 0..lanes {
            unsafe { out.write((b0 + l) * cols + c0 + ci, v.lane(l)) };
        }
    }
}

/// Batched LUT binary GEMM: `Y = X·W` for a packed ±alpha matrix,
/// `X` row-major `(batch, rows)`, `Y` row-major `(batch, cols)`
/// (overwritten). Per-row math is bit-identical to
/// [`super::gemv_lut::gemv_binary_lut`].
pub fn gemm_binary_lut(w: &PackedBinary, x: &[f32], batch: usize,
                       y: &mut [f32], scratch: &mut GemmScratch) {
    assert_eq!(x.len(), batch * w.rows);
    assert_eq!(y.len(), batch * w.cols);
    if batch == 0 {
        return;
    }
    let out = SharedOut::new(y);
    // SAFETY: one shard covering every column of `y`, which stays
    // borrowed (and otherwise untouched) for the duration of the call.
    unsafe { gemm_binary_lut_cols(w, x, batch, 0, w.cols, out, scratch) }
}

/// Column shard `[c0, c1)` of [`gemm_binary_lut`]. Streams only those
/// columns' sign-plane bytes.
///
/// # Safety
/// `out` must view a live row-major `(batch, w.cols)` buffer, and no
/// concurrent shard may overlap this one's column range.
pub unsafe fn gemm_binary_lut_cols(w: &PackedBinary, x: &[f32], batch: usize,
                                   c0: usize, c1: usize, out: SharedOut,
                                   scratch: &mut GemmScratch) {
    debug_assert_eq!(x.len(), batch * w.rows);
    debug_assert_eq!(out.len(), batch * w.cols);
    debug_assert!(c0 <= c1 && c1 <= w.cols);
    if batch == 0 || c0 == c1 {
        return;
    }
    let wpc = words_per_col(w.rows);
    let stride = wpc * 8;
    let groups = w.rows.div_ceil(8);
    let ncols = c1 - c0;
    scratch.ensure(ncols, batch);
    let GemmScratch { tables, xt, acc, totals } = scratch;
    // per-row prefix sum, same summation order as the per-slot kernel
    for b in 0..batch {
        totals[b] = x[b * w.rows..(b + 1) * w.rows].iter().sum();
    }
    let sign = le_bytes(&w.sign);
    let two = F32x8::splat(2.0);
    let alpha = F32x8::splat(w.alpha);
    for b0 in (0..batch).step_by(LANES) {
        let lanes = (batch - b0).min(LANES);
        // start from "all sign bits clear" = -Σx per live lane; dead
        // tail lanes run on zeros and are masked out at fold time
        let mut init = [0.0f32; LANES];
        for (l, v) in init[..lanes].iter_mut().enumerate() {
            *v = -totals[b0 + l];
        }
        let init = F32x8(init);
        for a in acc[..ncols].iter_mut() {
            *a = init;
        }
        for g in 0..groups {
            gather_tile(x, w.rows, b0, lanes, g, xt);
            build_subset_sums_tile(xt, tables);
            for (ci, a) in acc[..ncols].iter_mut().enumerate() {
                let t = tables[sign[(c0 + ci) * stride + g] as usize];
                *a = *a + two * t;
            }
        }
        // SAFETY: forwarded from this function's contract.
        unsafe { fold_tile(&acc[..ncols], alpha, b0, lanes, c0, w.cols, out) };
    }
}

/// Batched LUT ternary GEMM over the sign/mask packing; per-row math is
/// bit-identical to [`super::gemv_lut::gemv_ternary_lut`].
pub fn gemm_ternary_lut(w: &PackedTernary, x: &[f32], batch: usize,
                        y: &mut [f32], scratch: &mut GemmScratch) {
    assert_eq!(x.len(), batch * w.rows);
    assert_eq!(y.len(), batch * w.cols);
    if batch == 0 {
        return;
    }
    let out = SharedOut::new(y);
    // SAFETY: one shard covering every column of `y` (see above).
    unsafe { gemm_ternary_lut_cols(w, x, batch, 0, w.cols, out, scratch) }
}

/// Column shard `[c0, c1)` of [`gemm_ternary_lut`].
///
/// # Safety
/// Same contract as [`gemm_binary_lut_cols`].
pub unsafe fn gemm_ternary_lut_cols(w: &PackedTernary, x: &[f32],
                                    batch: usize, c0: usize, c1: usize,
                                    out: SharedOut,
                                    scratch: &mut GemmScratch) {
    debug_assert_eq!(x.len(), batch * w.rows);
    debug_assert_eq!(out.len(), batch * w.cols);
    debug_assert!(c0 <= c1 && c1 <= w.cols);
    if batch == 0 || c0 == c1 {
        return;
    }
    let wpc = words_per_col(w.rows);
    let stride = wpc * 8;
    let groups = w.rows.div_ceil(8);
    let ncols = c1 - c0;
    scratch.ensure(ncols, batch);
    let GemmScratch { tables, xt, acc, .. } = scratch;
    let sign = le_bytes(&w.sign);
    let mask = le_bytes(&w.mask);
    let alpha = F32x8::splat(w.alpha);
    for b0 in (0..batch).step_by(LANES) {
        let lanes = (batch - b0).min(LANES);
        acc[..ncols].fill(F32x8::ZERO);
        for g in 0..groups {
            gather_tile(x, w.rows, b0, lanes, g, xt);
            build_subset_sums_tile(xt, tables);
            for (ci, a) in acc[..ncols].iter_mut().enumerate() {
                let idx = (c0 + ci) * stride + g;
                let (m, s) = (mask[idx], sign[idx]);
                let tp = tables[(m & s) as usize];
                let tn = tables[(m & !s) as usize];
                *a = *a + (tp - tn);
            }
        }
        // SAFETY: forwarded from this function's contract.
        unsafe { fold_tile(&acc[..ncols], alpha, b0, lanes, c0, w.cols, out) };
    }
}

/// Batched GEMM over precomputed pos/neg selector planes — the
/// wide-batch layout of [`super::planes`], and the closest software
/// analogue of the accelerator: two selector-plane bytes are read per
/// (group, column) **for a whole lane tile**, with no byte-ops in the
/// loop. Per-row math is bit-identical to
/// [`super::planes::gemv_ternary_planes`].
pub fn gemm_ternary_planes(w: &TernaryPlanes, x: &[f32], batch: usize,
                           y: &mut [f32], scratch: &mut GemmScratch) {
    assert_eq!(x.len(), batch * w.rows);
    assert_eq!(y.len(), batch * w.cols);
    if batch == 0 {
        return;
    }
    let out = SharedOut::new(y);
    // SAFETY: one shard covering every column of `y` (see above).
    unsafe { gemm_ternary_planes_cols(w, x, batch, 0, w.cols, out, scratch) }
}

/// Column shard `[c0, c1)` of [`gemm_ternary_planes`].
///
/// # Safety
/// Same contract as [`gemm_binary_lut_cols`].
pub unsafe fn gemm_ternary_planes_cols(w: &TernaryPlanes, x: &[f32],
                                       batch: usize, c0: usize, c1: usize,
                                       out: SharedOut,
                                       scratch: &mut GemmScratch) {
    debug_assert_eq!(x.len(), batch * w.rows);
    debug_assert_eq!(out.len(), batch * w.cols);
    debug_assert!(c0 <= c1 && c1 <= w.cols);
    if batch == 0 || c0 == c1 {
        return;
    }
    let wpc = words_per_col(w.rows);
    let stride = wpc * 8;
    let groups = w.rows.div_ceil(8);
    let ncols = c1 - c0;
    scratch.ensure(ncols, batch);
    let GemmScratch { tables, xt, acc, .. } = scratch;
    let pos = le_bytes(&w.pos);
    let neg = le_bytes(&w.neg);
    let alpha = F32x8::splat(w.alpha);
    for b0 in (0..batch).step_by(LANES) {
        let lanes = (batch - b0).min(LANES);
        acc[..ncols].fill(F32x8::ZERO);
        for g in 0..groups {
            gather_tile(x, w.rows, b0, lanes, g, xt);
            build_subset_sums_tile(xt, tables);
            for (ci, a) in acc[..ncols].iter_mut().enumerate() {
                let idx = (c0 + ci) * stride + g;
                let tp = tables[pos[idx] as usize];
                let tn = tables[neg[idx] as usize];
                *a = *a + (tp - tn);
            }
        }
        // SAFETY: forwarded from this function's contract.
        unsafe { fold_tile(&acc[..ncols], alpha, b0, lanes, c0, w.cols, out) };
    }
}

/// Column shard of the dense-f32 `Y = X·W + bias` the LM head runs over
/// the gathered active rows: for each row `j` of the `(batch, rows)`
/// block `x`, writes `out[row_of[j]*cols + c] = Σ_r x[j,r]·w[r,c] +
/// bias[c]` for `c` in `[c0, c1)`. `row_of` maps block rows to output
/// rows, so callers can scatter straight into active slots' logit rows
/// and never touch idle rows. Per-element f32 op sequence (ascending-`r`
/// accumulation from 0, then one bias add) is identical to
/// [`super::gemv::gemv_f32`] + a bias loop — the per-slot reference
/// head path — so results are bit-identical for every shard split.
///
/// # Safety
/// `out` must view a live buffer of at least `(max(row_of)+1) * cols`
/// elements, and no concurrent shard may overlap this one's column
/// range.
pub unsafe fn gemm_f32_bias_cols(w: &[f32], rows: usize, cols: usize,
                                 x: &[f32], bias: &[f32], row_of: &[usize],
                                 c0: usize, c1: usize, out: SharedOut) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(x.len(), row_of.len() * rows);
    debug_assert_eq!(bias.len(), cols);
    debug_assert!(c0 <= c1 && c1 <= cols);
    // Column blocks with an r-outer inner loop, so `w` is read in
    // contiguous runs (the streaming access pattern of `gemv_f32`, not
    // a stride-`cols` column walk). Per element this is still the same
    // ascending-r accumulation from 0.0 — the independent per-column
    // sums don't care which loop is outermost — so the bit-exactness
    // contract is unchanged.
    const BLK: usize = 64;
    let mut acc = [0.0f32; BLK];
    for (j, &orow) in row_of.iter().enumerate() {
        let xr = &x[j * rows..(j + 1) * rows];
        let mut c = c0;
        while c < c1 {
            let n = (c1 - c).min(BLK);
            acc[..n].fill(0.0);
            for (r, &xv) in xr.iter().enumerate() {
                let wrow = &w[r * cols + c..r * cols + c + n];
                for (a, &wv) in acc[..n].iter_mut().zip(wrow) {
                    *a += xv * wv;
                }
            }
            for (k, &a) in acc[..n].iter().enumerate() {
                // SAFETY: forwarded from this function's contract.
                unsafe { out.write(orow * cols + c + k, a + bias[c + k]) };
            }
            c += n;
        }
    }
}

/// Integer xnor/popcount accumulators for the binarized recurrent GEMM
/// (`Datapath::Xnor`): for each batch row `j` (sign words `xwords[j*wpc
/// ..]`, bit set = +1) and column `c` in `[c0, c1)`, computes the exact
/// ±1 dot product
///
/// ```text
/// acc[j*(c1-c0) + (c-c0)] = Σ_r sign(x[j,r]) · w[r,c]   (w ∈ {-1,0,+1})
/// ```
///
/// entirely in i32 — **no float enters the accumulation**, which is the
/// paper's accumulator-only datapath taken literally and what the
/// property tests pin bit-for-bit against a dense ±1 integer reference.
/// Per layout:
///
/// * binary: matches = popcount(xnor(x, sign) & valid) per word (the
///   `valid` mask zeroes padding rows in the last word, where a clear
///   sign bit would otherwise read as a spurious −1), `dot =
///   2·matches − rows`;
/// * ternary/planes: `dot = (2·pc(x & pos) − |pos|) − (2·pc(x & neg) −
///   |neg|)` with the per-column plane populations `|pos|`/`|neg|`
///   hoisted out of the batch loop (plane padding bits are packed zero,
///   so no mask is needed).
pub fn gemm_xnor_acc_cols(w: &Packed, xwords: &[u64], batch: usize,
                          c0: usize, c1: usize, acc: &mut [i32]) {
    let rows = w.rows();
    let wpc = words_per_col(rows);
    let ncols = c1 - c0;
    debug_assert!(c0 <= c1 && c1 <= w.cols());
    debug_assert_eq!(xwords.len(), batch * wpc);
    debug_assert!(acc.len() >= batch * ncols);
    if batch == 0 || ncols == 0 {
        return;
    }
    let tail = rows % 64;
    let valid_last = if tail == 0 { u64::MAX } else { (1u64 << tail) - 1 };
    match w {
        Packed::Binary(b) => {
            for ci in 0..ncols {
                let sw = &b.sign[(c0 + ci) * wpc..(c0 + ci + 1) * wpc];
                for j in 0..batch {
                    let xw = &xwords[j * wpc..(j + 1) * wpc];
                    let mut matches = 0i32;
                    for wi in 0..wpc {
                        let valid =
                            if wi + 1 == wpc { valid_last } else { u64::MAX };
                        matches += (!(xw[wi] ^ sw[wi]) & valid)
                            .count_ones() as i32;
                    }
                    acc[j * ncols + ci] = 2 * matches - rows as i32;
                }
            }
        }
        Packed::Ternary(t) => {
            for ci in 0..ncols {
                let base = (c0 + ci) * wpc;
                let sw = &t.sign[base..base + wpc];
                let mw = &t.mask[base..base + wpc];
                let mut npos = 0i32;
                let mut nneg = 0i32;
                for wi in 0..wpc {
                    npos += (mw[wi] & sw[wi]).count_ones() as i32;
                    nneg += (mw[wi] & !sw[wi]).count_ones() as i32;
                }
                for j in 0..batch {
                    let xw = &xwords[j * wpc..(j + 1) * wpc];
                    let mut pc_pos = 0i32;
                    let mut pc_neg = 0i32;
                    for wi in 0..wpc {
                        pc_pos += (xw[wi] & mw[wi] & sw[wi])
                            .count_ones() as i32;
                        pc_neg += (xw[wi] & mw[wi] & !sw[wi])
                            .count_ones() as i32;
                    }
                    acc[j * ncols + ci] =
                        (2 * pc_pos - npos) - (2 * pc_neg - nneg);
                }
            }
        }
        Packed::Planes(p) => {
            for ci in 0..ncols {
                let base = (c0 + ci) * wpc;
                let pw = &p.pos[base..base + wpc];
                let nw = &p.neg[base..base + wpc];
                let npos: i32 =
                    pw.iter().map(|w| w.count_ones() as i32).sum();
                let nneg: i32 =
                    nw.iter().map(|w| w.count_ones() as i32).sum();
                for j in 0..batch {
                    let xw = &xwords[j * wpc..(j + 1) * wpc];
                    let mut pc_pos = 0i32;
                    let mut pc_neg = 0i32;
                    for wi in 0..wpc {
                        pc_pos += (xw[wi] & pw[wi]).count_ones() as i32;
                        pc_neg += (xw[wi] & nw[wi]).count_ones() as i32;
                    }
                    acc[j * ncols + ci] =
                        (2 * pc_pos - npos) - (2 * pc_neg - nneg);
                }
            }
        }
    }
}

/// Column shard `[c0, c1)` of the binarized recurrent GEMM: the integer
/// accumulators of [`gemm_xnor_acc_cols`] dequantized by the per-row
/// binarization scale and the weight alpha — `y[j,c] = alpha · s_j ·
/// acc[j,c]`. Same [`SharedOut`] disjoint-column contract (and the same
/// `shard_range` fan-out) as the LUT `*_cols` kernels, so `engine::pool`
/// and cluster sharding work unchanged.
///
/// # Safety
/// Same contract as [`gemm_binary_lut_cols`].
pub unsafe fn gemm_xnor_cols(w: &Packed, xb: &BinarizedBatch, batch: usize,
                             c0: usize, c1: usize, out: SharedOut,
                             scratch: &mut GemmScratch) {
    let cols = w.cols();
    let ncols = c1 - c0;
    debug_assert_eq!(xb.rows, w.rows());
    debug_assert_eq!(out.len(), batch * cols);
    debug_assert!(c0 <= c1 && c1 <= cols);
    if batch == 0 || ncols == 0 {
        return;
    }
    if scratch.xnor.len() < batch * ncols {
        scratch.xnor.resize(batch * ncols, 0);
    }
    let wpc = words_per_col(w.rows());
    gemm_xnor_acc_cols(w, &xb.words[..batch * wpc], batch, c0, c1,
                       &mut scratch.xnor);
    let alpha = match w {
        Packed::Binary(b) => b.alpha,
        Packed::Ternary(t) => t.alpha,
        Packed::Planes(p) => p.alpha,
    };
    for j in 0..batch {
        let s = alpha * xb.scales[j];
        for ci in 0..ncols {
            let v = s * scratch.xnor[j * ncols + ci] as f32;
            // SAFETY: forwarded from this function's contract.
            unsafe { out.write(j * cols + c0 + ci, v) };
        }
    }
}

/// Full-width binarized recurrent GEMM: `Y = binarize(X)·W` with
/// per-row scale correction, `Y` row-major `(batch, cols)`
/// (overwritten). See [`gemm_xnor_acc_cols`] for the integer core.
pub fn gemm_xnor(w: &Packed, xb: &BinarizedBatch, batch: usize,
                 y: &mut [f32], scratch: &mut GemmScratch) {
    assert_eq!(y.len(), batch * w.cols());
    if batch == 0 {
        return;
    }
    let out = SharedOut::new(y);
    // SAFETY: one shard covering every column of `y` (see above).
    unsafe { gemm_xnor_cols(w, xb, batch, 0, w.cols(), out, scratch) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gemv_lut::{gemv_binary_lut, gemv_ternary_lut, LutScratch};
    use crate::quant::planes::gemv_ternary_planes;
    use crate::util::Rng;

    fn rand_ternary(rng: &mut Rng, n: usize, alpha: f32) -> Vec<f32> {
        (0..n).map(|_| [0.0, alpha, -alpha][rng.below_usize(3)]).collect()
    }

    #[test]
    fn batched_binary_matches_per_slot_bitwise() {
        let mut rng = Rng::new(51);
        // batches straddle the 8-lane tile: 1 (mostly-dead tile), 7
        // (masked tail only), 8 (exactly one tile), 9 (tile + 1-lane
        // tail), 16 and 64 (multiple full tiles)
        for (rows, cols, batch) in [(64, 16, 4), (100, 37, 1), (7, 3, 5),
                                    (129, 8, 16), (65, 12, 3), (64, 16, 7),
                                    (100, 37, 8), (65, 12, 9), (33, 20, 64)] {
            let alpha = 0.2f32;
            let w: Vec<f32> = (0..rows * cols)
                .map(|_| if rng.bernoulli(0.5) { alpha } else { -alpha })
                .collect();
            let packed = PackedBinary::pack(&w, rows, cols, alpha);
            let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0f32; batch * cols];
            let mut s = GemmScratch::default();
            gemm_binary_lut(&packed, &x, batch, &mut y, &mut s);
            let mut ls = LutScratch::default();
            for b in 0..batch {
                let mut yb = vec![0.0f32; cols];
                gemv_binary_lut(&packed, &x[b * rows..(b + 1) * rows], &mut yb,
                                &mut ls);
                for c in 0..cols {
                    assert_eq!(y[b * cols + c].to_bits(), yb[c].to_bits(),
                               "({rows},{cols}) b {b} col {c}");
                }
            }
        }
    }

    #[test]
    fn batched_ternary_matches_per_slot_bitwise() {
        let mut rng = Rng::new(53);
        for (rows, cols, batch) in [(64, 16, 4), (100, 37, 2), (5, 2, 7),
                                    (513, 24, 8), (64, 16, 9), (37, 11, 64)] {
            let alpha = 0.15f32;
            let w = rand_ternary(&mut rng, rows * cols, alpha);
            let packed = PackedTernary::pack(&w, rows, cols, alpha);
            let planes = TernaryPlanes::from_packed(&packed);
            let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal_f32()).collect();
            let mut y_lut = vec![0.0f32; batch * cols];
            let mut y_pl = vec![0.0f32; batch * cols];
            let mut s = GemmScratch::default();
            gemm_ternary_lut(&packed, &x, batch, &mut y_lut, &mut s);
            gemm_ternary_planes(&planes, &x, batch, &mut y_pl, &mut s);
            let mut ls = LutScratch::default();
            for b in 0..batch {
                let xb = &x[b * rows..(b + 1) * rows];
                let mut y1 = vec![0.0f32; cols];
                gemv_ternary_lut(&packed, xb, &mut y1, &mut ls);
                let mut y2 = vec![0.0f32; cols];
                gemv_ternary_planes(&planes, xb, &mut y2, &mut ls);
                for c in 0..cols {
                    assert_eq!(y_lut[b * cols + c].to_bits(), y1[c].to_bits(),
                               "lut ({rows},{cols}) b {b} col {c}");
                    assert_eq!(y_pl[b * cols + c].to_bits(), y2[c].to_bits(),
                               "planes ({rows},{cols}) b {b} col {c}");
                }
            }
        }
    }

    #[test]
    fn column_shards_reassemble_the_full_gemm() {
        // Any column split must reproduce the one-shard result exactly —
        // the invariant that makes thread-count irrelevant to logits.
        let mut rng = Rng::new(57);
        let (rows, cols, batch) = (70, 29, 11);
        let alpha = 0.15f32;
        let w = rand_ternary(&mut rng, rows * cols, alpha);
        let packed = PackedTernary::pack(&w, rows, cols, alpha);
        let planes = TernaryPlanes::from_packed(&packed);
        let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal_f32()).collect();
        let mut s = GemmScratch::default();
        let mut whole = vec![0.0f32; batch * cols];
        gemm_ternary_planes(&planes, &x, batch, &mut whole, &mut s);
        for splits in [vec![0, 29], vec![0, 1, 29], vec![0, 7, 13, 28, 29]] {
            let mut sharded = vec![f32::NAN; batch * cols];
            {
                let out = SharedOut::new(&mut sharded);
                for pair in splits.windows(2) {
                    // SAFETY: shards cover disjoint [c0, c1) ranges and
                    // `sharded` outlives them (sequential here).
                    unsafe {
                        gemm_ternary_planes_cols(&planes, &x, batch, pair[0],
                                                 pair[1], out, &mut s);
                    }
                }
            }
            for (i, (a, b)) in whole.iter().zip(&sharded).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "splits {splits:?} elt {i}");
            }
        }
    }

    #[test]
    fn scratch_capacity_is_stable_across_alternating_batches() {
        // Continuous batching alternates batch widths every step; the
        // scratch must reach steady state after the widest batch and
        // never shrink-then-regrow (no allocator traffic in the hot
        // loop).
        let mut rng = Rng::new(59);
        let (rows, cols) = (48, 24);
        let alpha = 0.1f32;
        let w = rand_ternary(&mut rng, rows * cols, alpha);
        let packed = PackedTernary::pack(&w, rows, cols, alpha);
        let mut s = GemmScratch::default();
        let run = |s: &mut GemmScratch, batch: usize, rng: &mut Rng| {
            let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0f32; batch * cols];
            gemm_ternary_lut(&packed, &x, batch, &mut y, s);
        };
        run(&mut s, 64, &mut rng); // widest batch first: steady state
        let caps = (s.tables.capacity(), s.xt.capacity(), s.acc.capacity(),
                    s.totals.capacity());
        let ptrs = (s.tables.as_ptr(), s.acc.as_ptr(), s.totals.as_ptr());
        let lens = (s.tables.len(), s.xt.len(), s.acc.len(), s.totals.len());
        for batch in [1usize, 9, 64, 3, 64, 8, 1, 64] {
            run(&mut s, batch, &mut rng);
            assert_eq!((s.tables.capacity(), s.xt.capacity(), s.acc.capacity(),
                        s.totals.capacity()), caps,
                       "capacity changed at batch {batch}");
            assert_eq!((s.tables.as_ptr(), s.acc.as_ptr(), s.totals.as_ptr()),
                       ptrs, "buffer reallocated at batch {batch}");
            assert_eq!((s.tables.len(), s.xt.len(), s.acc.len(),
                        s.totals.len()), lens,
                       "len shrank at batch {batch} (grow-only violated)");
        }
    }

    #[test]
    fn dense_bias_cols_match_gemv_reference() {
        use crate::quant::gemv_f32;
        let mut rng = Rng::new(61);
        let (rows, cols, batch) = (23, 17, 5);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.normal_f32()).collect();
        let bias: Vec<f32> = (0..cols).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> = (0..batch * rows).map(|_| rng.normal_f32()).collect();
        // scatter rows 0..batch into output rows 2*j of a wider buffer
        let row_of: Vec<usize> = (0..batch).map(|j| 2 * j).collect();
        let mut y = vec![f32::NAN; 2 * batch * cols];
        {
            let out = SharedOut::new(&mut y);
            // SAFETY: disjoint shards, buffer outlives them.
            unsafe {
                gemm_f32_bias_cols(&w, rows, cols, &x, &bias, &row_of, 0, 9, out);
                gemm_f32_bias_cols(&w, rows, cols, &x, &bias, &row_of, 9, cols,
                                   out);
            }
        }
        for j in 0..batch {
            let mut want = vec![0.0f32; cols];
            gemv_f32(&w, rows, cols, &x[j * rows..(j + 1) * rows], &mut want);
            for c in 0..cols {
                let got = y[2 * j * cols + c];
                assert_eq!(got.to_bits(), (want[c] + bias[c]).to_bits(),
                           "row {j} col {c}");
            }
            // the in-between rows were never written
            assert!(y[(2 * j + 1) * cols..(2 * j + 2) * cols]
                        .iter().all(|v| v.is_nan()));
        }
    }

    #[test]
    fn zero_batch_is_a_noop() {
        let w = PackedTernary::pack(&[1.0f32, -1.0, 0.0, 1.0], 4, 1, 1.0);
        let planes = TernaryPlanes::from_packed(&w);
        let mut s = GemmScratch::default();
        let mut y: Vec<f32> = vec![];
        gemm_ternary_lut(&w, &[], 0, &mut y, &mut s);
        gemm_ternary_planes(&planes, &[], 0, &mut y, &mut s);
        let b = PackedBinary::pack(&[1.0f32, -1.0, 1.0, 1.0], 4, 1, 1.0);
        gemm_binary_lut(&b, &[], 0, &mut y, &mut s);
        gemm_xnor(&Packed::Ternary(w), &BinarizedBatch::default(), 0,
                  &mut y, &mut s);
    }

    /// Dense ±1 integer reference for the xnor accumulator: sign(x) ∈
    /// {+1, -1} (ties to +1), w ∈ {-1, 0, +1}, plain i32 adds.
    fn dense_pm1_acc(wd: &[f32], rows: usize, cols: usize, x: &[f32],
                     batch: usize, alpha: f32) -> Vec<i32> {
        let mut acc = vec![0i32; batch * cols];
        for j in 0..batch {
            for c in 0..cols {
                let mut dot = 0i32;
                for r in 0..rows {
                    let xs = if x[j * rows + r] >= 0.0 { 1 } else { -1 };
                    let ws = if wd[r * cols + c] > alpha * 0.5 {
                        1
                    } else if wd[r * cols + c] < -alpha * 0.5 {
                        -1
                    } else {
                        0
                    };
                    dot += xs * ws;
                }
                acc[j * cols + c] = dot;
            }
        }
        acc
    }

    #[test]
    fn xnor_accumulator_matches_dense_pm1_reference_exactly() {
        // every packed layout, rows straddling word boundaries, batches
        // straddling the lane tile — the i32 accumulators must be EQUAL
        // (integers: no tolerance)
        let mut rng = Rng::new(71);
        for (rows, cols) in [(64, 16), (70, 9), (128, 5), (33, 21)] {
            for batch in [1usize, 7, 8, 9, 64] {
                let alpha = 0.2f32;
                let ter = rand_ternary(&mut rng, rows * cols, alpha);
                let bin: Vec<f32> = (0..rows * cols)
                    .map(|_| if rng.bernoulli(0.5) { alpha } else { -alpha })
                    .collect();
                let x: Vec<f32> =
                    (0..batch * rows).map(|_| rng.normal_f32()).collect();
                let mut xb = BinarizedBatch::default();
                xb.pack(&x, batch, rows);
                let pt = PackedTernary::pack(&ter, rows, cols, alpha);
                let layouts: Vec<(&str, Packed, &[f32])> = vec![
                    ("binary",
                     Packed::Binary(PackedBinary::pack(&bin, rows, cols,
                                                       alpha)),
                     &bin),
                    ("ternary", Packed::Ternary(pt.clone()), &ter),
                    ("planes",
                     Packed::Planes(TernaryPlanes::from_packed(&pt)), &ter),
                ];
                for (name, w, wd) in layouts {
                    let want = dense_pm1_acc(wd, rows, cols, &x, batch, alpha);
                    let mut acc = vec![0i32; batch * cols];
                    gemm_xnor_acc_cols(&w, &xb.words, batch, 0, cols,
                                       &mut acc);
                    assert_eq!(acc, want,
                               "{name} ({rows},{cols}) batch {batch}");
                }
            }
        }
    }

    #[test]
    fn xnor_column_shards_reassemble_the_full_gemm() {
        let mut rng = Rng::new(73);
        let (rows, cols, batch) = (70, 29, 11);
        let alpha = 0.15f32;
        let ter = rand_ternary(&mut rng, rows * cols, alpha);
        let w = Packed::Ternary(PackedTernary::pack(&ter, rows, cols, alpha));
        let x: Vec<f32> =
            (0..batch * rows).map(|_| rng.normal_f32()).collect();
        let mut xb = BinarizedBatch::default();
        xb.pack(&x, batch, rows);
        let mut s = GemmScratch::default();
        let mut whole = vec![0.0f32; batch * cols];
        gemm_xnor(&w, &xb, batch, &mut whole, &mut s);
        for splits in [vec![0, 1, 29], vec![0, 7, 13, 28, 29]] {
            let mut sharded = vec![f32::NAN; batch * cols];
            {
                let out = SharedOut::new(&mut sharded);
                for pair in splits.windows(2) {
                    // SAFETY: disjoint column shards, buffer outlives them.
                    unsafe {
                        gemm_xnor_cols(&w, &xb, batch, pair[0], pair[1], out,
                                       &mut s);
                    }
                }
            }
            for (i, (a, b)) in whole.iter().zip(&sharded).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "splits {splits:?} elt {i}");
            }
        }
    }

    #[test]
    fn xnor_scale_fold_and_zero_rows() {
        // y = alpha * s_j * dot, with a zeroed row contributing exactly 0
        let alpha = 0.5f32;
        let wd = vec![alpha; 4 * 3]; // all +1
        let w = Packed::Binary(PackedBinary::pack(&wd, 4, 3, alpha));
        let x = [1.0f32, -2.0, 3.0, -4.0, 0.0, 0.0, 0.0, 0.0];
        let mut xb = BinarizedBatch::default();
        xb.pack(&x, 2, 4);
        let mut s = GemmScratch::default();
        let mut y = vec![f32::NAN; 2 * 3];
        gemm_xnor(&w, &xb, 2, &mut y, &mut s);
        // row 0: signs [+,-,+,-] vs all +1 => dot 0 => y 0
        // row 1: zero h => scale 0 => y exactly 0 despite dot = 4
        for (i, v) in y.iter().enumerate() {
            assert_eq!(v.to_bits(), 0.0f32.to_bits(), "elt {i}");
        }
    }
}
