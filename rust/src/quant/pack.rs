//! Bit-plane packing of binary/ternary weight matrices.
//!
//! This is the storage format the paper's accelerator reads from DRAM:
//! 1 bit per binary weight, 2 bits per ternary weight (a sign plane and a
//! non-zero mask plane), versus 12-bit fixed point in the full-precision
//! baseline — the source of the 12× memory/bandwidth saving of §6.
//!
//! Layout: matrices are (k, n) with the contraction dimension k packed
//! along u64 words column-major — column j's plane occupies words
//! `[j*wpc .. (j+1)*wpc)` with bit b of word w covering row `64*w + b`.
//! This keeps a GEMV inner loop sequential in memory per output column.
//!
//! Plane words live behind `Arc<[u64]>`: a packed matrix is immutable
//! after packing, so clones are reference bumps, never byte copies. This
//! is what lets N serving shards (see `crate::cluster`) serve from ONE
//! resident copy of the planes — the paper's 12× memory saving must not
//! be multiplied back by replication. `plane_ptr`/`plane_owners` expose
//! the shared allocation for identity/refcount assertions.

use std::sync::Arc;

pub(crate) const FNV_OFFSET: u64 = 0xcbf29ce484222325;
pub(crate) const FNV_PRIME: u64 = 0x100000001b3;

/// Feed bytes into a running FNV-1a hash — the integrity-fingerprint
/// primitive shared by the packed layouts.
pub(crate) fn fnv_feed(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

pub(crate) fn fnv_words(h: &mut u64, words: &[u64]) {
    for &w in words {
        fnv_feed(h, &w.to_le_bytes());
    }
}

/// Flip `bit` of word `word % words.len()` in a copy of `words` — the
/// plane-corruption primitive of the chaos harness
/// ([`crate::faults::Fault::PlaneBitFlip`]). The planes themselves are
/// immutable behind `Arc`, so corruption is modeled as a rebuilt
/// allocation, exactly like a corrupt checkpoint read.
pub(crate) fn flipped_words(words: &[u64], word: usize, bit: u32)
    -> Arc<[u64]> {
    let mut v: Vec<u64> = words.to_vec();
    let w = word % v.len().max(1);
    v[w] ^= 1u64 << (bit % 64);
    v.into()
}

/// A packed binary matrix: values in {-alpha, +alpha}.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedBinary {
    pub rows: usize,
    pub cols: usize,
    pub alpha: f32,
    /// sign plane: bit set => +1, clear => -1; cols * words_per_col words.
    /// Shared: clones alias the same allocation.
    pub sign: Arc<[u64]>,
}

/// A packed ternary matrix: values in {-alpha, 0, +alpha}.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTernary {
    pub rows: usize,
    pub cols: usize,
    pub alpha: f32,
    /// sign plane: bit set => positive (only meaningful where mask set).
    /// Shared: clones alias the same allocation.
    pub sign: Arc<[u64]>,
    /// mask plane: bit set => non-zero. Shared like `sign`.
    pub mask: Arc<[u64]>,
}

/// Words per packed column for `rows` entries.
pub fn words_per_col(rows: usize) -> usize {
    rows.div_ceil(64)
}

impl PackedBinary {
    /// Pack a column-major-logical (rows, cols) f32 matrix whose entries
    /// are ±alpha (or ±1 times alpha). `data` is row-major (rows × cols),
    /// matching the artifact export layout.
    pub fn pack(data: &[f32], rows: usize, cols: usize, alpha: f32) -> Self {
        assert_eq!(data.len(), rows * cols);
        let wpc = words_per_col(rows);
        let mut sign = vec![0u64; cols * wpc];
        for r in 0..rows {
            for c in 0..cols {
                if data[r * cols + c] > 0.0 {
                    sign[c * wpc + r / 64] |= 1u64 << (r % 64);
                }
            }
        }
        Self { rows, cols, alpha, sign: sign.into() }
    }

    /// Unpack to a row-major f32 matrix (±alpha).
    pub fn unpack(&self) -> Vec<f32> {
        let wpc = words_per_col(self.rows);
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                let bit = (self.sign[c * wpc + r / 64] >> (r % 64)) & 1;
                out[r * self.cols + c] = if bit == 1 { self.alpha } else { -self.alpha };
            }
        }
        out
    }

    /// Bytes occupied by the packed planes (the Size columns).
    pub fn packed_bytes(&self) -> usize {
        self.sign.len() * 8
    }

    /// Address of the sign-plane allocation — identical across shared
    /// clones (pointer-identity proof that no plane bytes were copied).
    pub fn plane_ptr(&self) -> *const u64 {
        self.sign.as_ptr()
    }

    /// Live owners of the sign-plane allocation (1 = unshared).
    pub fn plane_owners(&self) -> usize {
        Arc::strong_count(&self.sign)
    }

    /// FNV-1a fingerprint over dims, alpha bits, and every sign-plane
    /// word — taken at pack time, re-verified at load so a corrupt
    /// checkpoint is a typed error, not wrong logits.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        fnv_feed(&mut h, b"bin");
        fnv_feed(&mut h, &(self.rows as u64).to_le_bytes());
        fnv_feed(&mut h, &(self.cols as u64).to_le_bytes());
        fnv_feed(&mut h, &self.alpha.to_bits().to_le_bytes());
        fnv_words(&mut h, &self.sign);
        h
    }

    /// A copy with one sign-plane bit flipped (chaos harness only).
    pub fn with_flipped_bit(&self, word: usize, bit: u32) -> Self {
        Self { sign: flipped_words(&self.sign, word, bit), ..self.clone() }
    }
}

impl PackedTernary {
    /// Pack a row-major (rows, cols) f32 matrix with entries in
    /// {-alpha, 0, +alpha}. Zero tolerance: |x| <= alpha/2 packs to 0 —
    /// exact 0.0 from the quantizer always does.
    pub fn pack(data: &[f32], rows: usize, cols: usize, alpha: f32) -> Self {
        assert_eq!(data.len(), rows * cols);
        let wpc = words_per_col(rows);
        let mut sign = vec![0u64; cols * wpc];
        let mut mask = vec![0u64; cols * wpc];
        let half = alpha * 0.5;
        for r in 0..rows {
            for c in 0..cols {
                let x = data[r * cols + c];
                if x.abs() > half {
                    mask[c * wpc + r / 64] |= 1u64 << (r % 64);
                    if x > 0.0 {
                        sign[c * wpc + r / 64] |= 1u64 << (r % 64);
                    }
                }
            }
        }
        Self { rows, cols, alpha, sign: sign.into(), mask: mask.into() }
    }

    /// Unpack to a row-major f32 matrix.
    pub fn unpack(&self) -> Vec<f32> {
        let wpc = words_per_col(self.rows);
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                let w = c * wpc + r / 64;
                let b = r % 64;
                if (self.mask[w] >> b) & 1 == 1 {
                    out[r * self.cols + c] =
                        if (self.sign[w] >> b) & 1 == 1 { self.alpha } else { -self.alpha };
                }
            }
        }
        out
    }

    pub fn packed_bytes(&self) -> usize {
        (self.sign.len() + self.mask.len()) * 8
    }

    /// Address of the sign-plane allocation — identical across shared
    /// clones (the mask plane travels with it; both are `Arc`-backed).
    pub fn plane_ptr(&self) -> *const u64 {
        self.sign.as_ptr()
    }

    /// Live owners of the sign-plane allocation (1 = unshared).
    pub fn plane_owners(&self) -> usize {
        Arc::strong_count(&self.sign)
    }

    /// FNV-1a fingerprint over dims, alpha bits, and every sign- and
    /// mask-plane word (see [`PackedBinary::fingerprint`]). Covers sign
    /// bits under a cleared mask too: corruption is detected even where
    /// it would not change an unpacked value.
    pub fn fingerprint(&self) -> u64 {
        let mut h = FNV_OFFSET;
        fnv_feed(&mut h, b"ter");
        fnv_feed(&mut h, &(self.rows as u64).to_le_bytes());
        fnv_feed(&mut h, &(self.cols as u64).to_le_bytes());
        fnv_feed(&mut h, &self.alpha.to_bits().to_le_bytes());
        fnv_words(&mut h, &self.sign);
        fnv_words(&mut h, &self.mask);
        h
    }

    /// A copy with one sign-plane bit flipped (chaos harness only).
    pub fn with_flipped_bit(&self, word: usize, bit: u32) -> Self {
        Self { sign: flipped_words(&self.sign, word, bit), ..self.clone() }
    }

    /// Fraction of non-zero weights (Fig. 1a reports the ternary weight
    /// distribution being dominated by non-zeros).
    pub fn density(&self) -> f64 {
        let mut count = 0u64;
        let wpc = words_per_col(self.rows);
        for c in 0..self.cols {
            for w in 0..wpc {
                let mut word = self.mask[c * wpc + w];
                // mask out padding bits in the last word
                if w == wpc - 1 && self.rows % 64 != 0 {
                    word &= (1u64 << (self.rows % 64)) - 1;
                }
                count += word.count_ones() as u64;
            }
        }
        count as f64 / (self.rows * self.cols) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn binary_roundtrip() {
        let mut rng = Rng::new(1);
        let (rows, cols) = (67, 13); // deliberately not multiples of 64
        let alpha = 0.25;
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| if rng.bernoulli(0.5) { alpha } else { -alpha })
            .collect();
        let packed = PackedBinary::pack(&data, rows, cols, alpha);
        assert_eq!(packed.unpack(), data);
    }

    #[test]
    fn ternary_roundtrip() {
        let mut rng = Rng::new(2);
        let (rows, cols) = (130, 7);
        let alpha = 0.1;
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| [0.0f32, alpha, -alpha][rng.below_usize(3)])
            .collect();
        let packed = PackedTernary::pack(&data, rows, cols, alpha);
        assert_eq!(packed.unpack(), data);
    }

    #[test]
    fn packed_sizes() {
        let b = PackedBinary::pack(&vec![1.0; 64 * 4], 64, 4, 1.0);
        assert_eq!(b.packed_bytes(), 4 * 8); // one word per column
        let t = PackedTernary::pack(&vec![0.0; 64 * 4], 64, 4, 1.0);
        assert_eq!(t.packed_bytes(), 2 * 4 * 8); // two planes
    }

    #[test]
    fn clones_share_plane_allocations() {
        let b = PackedBinary::pack(&vec![1.0; 64 * 4], 64, 4, 1.0);
        let b2 = b.clone();
        assert_eq!(b.plane_ptr(), b2.plane_ptr());
        assert_eq!(b.plane_owners(), 2);
        let t = PackedTernary::pack(&vec![0.0; 64 * 4], 64, 4, 1.0);
        let t2 = t.clone();
        assert_eq!(t.plane_ptr(), t2.plane_ptr());
        assert_eq!(t2.plane_owners(), 2);
        drop(t2);
        assert_eq!(t.plane_owners(), 1);
    }

    #[test]
    fn fingerprints_are_stable_and_bit_sensitive() {
        let mut rng = Rng::new(5);
        let data: Vec<f32> = (0..96 * 6)
            .map(|_| [0.0f32, 0.5, -0.5][rng.below_usize(3)])
            .collect();
        let t = PackedTernary::pack(&data, 96, 6, 0.5);
        assert_eq!(t.fingerprint(), t.clone().fingerprint(),
                   "clones fingerprint identically");
        let corrupt = t.with_flipped_bit(3, 17);
        assert_ne!(t.fingerprint(), corrupt.fingerprint(),
                   "one flipped plane bit must change the fingerprint");
        // a sign flip under a cleared mask changes no unpacked value but
        // IS caught — silent datapath corruption stays detectable
        let masked_zero = (0..96 * 6).find(|i| data[*i] == 0.0).unwrap();
        let (r, c) = (masked_zero / 6, masked_zero % 6);
        let wpc = words_per_col(96);
        let silent = t.with_flipped_bit(c * wpc + r / 64, (r % 64) as u32);
        assert_eq!(silent.unpack(), t.unpack());
        assert_ne!(silent.fingerprint(), t.fingerprint());
        let b = PackedBinary::pack(&vec![1.0; 64 * 4], 64, 4, 1.0);
        assert_ne!(b.fingerprint(), b.with_flipped_bit(0, 0).fingerprint());
        assert_ne!(b.fingerprint(), t.fingerprint(),
                   "layout tag separates binary from ternary");
    }

    #[test]
    fn ternary_density() {
        let alpha = 1.0;
        let mut data = vec![0.0f32; 100 * 3];
        for c in &mut data[..150] {
            *c = alpha;
        }
        let t = PackedTernary::pack(&data, 100, 3, alpha);
        assert!((t.density() - 0.5).abs() < 1e-9);
    }
}
