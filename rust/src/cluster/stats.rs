//! Aggregated cluster telemetry: per-shard serving counters plus
//! whole-cluster throughput and latency percentiles.

use crate::coordinator::ServerStats;
use crate::obs::{LogHistogram, ShardStages};
use crate::session::SessionCounters;
use crate::util::stats::LatencySummary;

/// One shard worker's contribution to a cluster run.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Requests the router dispatched to this shard (policy-dependent).
    pub routed: u64,
    /// The shard's own continuous-batching counters.
    pub server: ServerStats,
    /// This shard's token throughput over the cluster wall time.
    pub tokens_per_sec: f64,
    /// True once the shard has been removed from the live fleet
    /// (`ServingCluster::remove_shard`); its counters are final and
    /// stay in the cluster totals.
    pub retired: bool,
}

/// Whole-cluster counters + latency percentiles for one serving run.
///
/// Totals are sums over shards; `tokens_per_sec` is total tokens over
/// the one shared wall clock (shards run concurrently, so per-shard
/// rates add). The latency summaries cover the full path — front-door
/// queue + shard inbox + shard admission queue (`queue`), slot
/// residency (`run`) and their sum (`total`).
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    pub shards: Vec<ShardStats>,
    pub completed: u64,
    pub tokens_processed: u64,
    pub engine_steps: u64,
    pub wall_s: f64,
    pub tokens_per_sec: f64,
    pub queue: LatencySummary,
    pub run: LatencySummary,
    pub total: LatencySummary,
    /// Session-cache gauges (prefix hits/misses, evictions, residency);
    /// `None` when the cluster runs without a session cache.
    pub sessions: Option<SessionCounters>,
    /// Shard-worker respawns performed by supervision (fleet-wide; 0 on
    /// a healthy run). Counters from a respawned shard re-count its
    /// replayed work, so totals stay monotonic across a crash rather
    /// than exactly-once.
    pub respawns: u64,
    /// Requests answered with a typed `Expired` outcome instead of
    /// being served (their deadline passed while still queued).
    pub expired: u64,
    /// `Full` admission refusals absorbed by retry backoff
    /// ([`super::RetrySpec`]) before the request was accepted/refused.
    pub retry_attempts: u64,
    /// Per-shard engine stage-time breakdown (x-GEMM / gate-GEMM /
    /// gate-tail / LM-head); empty unless tracing is on
    /// ([`crate::obs`]).
    pub stages: Vec<ShardStages>,
    /// Log-bucketed distributions over the same completion-latency
    /// samples the percentile summaries cover (always populated).
    pub queue_hist: LogHistogram,
    pub run_hist: LogHistogram,
    pub total_hist: LogHistogram,
}

impl ClusterStats {
    /// Largest routed-count imbalance between any two shards (0 =
    /// perfectly even; round-robin keeps this <= 1 by construction).
    pub fn routing_imbalance(&self) -> u64 {
        let routed = self.shards.iter().map(|s| s.routed);
        let hi = routed.clone().max().unwrap_or(0);
        let lo = routed.min().unwrap_or(0);
        hi - lo
    }
}
