//! Sharded serving cluster: one shared packed weight set, N engine-shard
//! workers, one async router — with live fleet operations.
//!
//! This is the "beyond one box" rung of the ROADMAP: the paper's §6
//! argument makes the weight stream the scarce resource, and PR 2/3
//! exploited that *within* one engine (one weight stream per step for
//! all decode slots, sharded across a thread pool). A single engine
//! worker thread is still the ceiling, though — this module scales out
//! by running **N whole engines** ([`crate::coordinator::InferenceServer`]
//! shards, each with its own decode loop on its own thread, its own
//! slots and its own GEMM thread pool) behind one front door.
//!
//! ## Shared-plane ownership
//!
//! Naively, N engines would mean N copies of the weights — multiplying
//! back exactly the 12× memory the paper saves. Instead the cluster owns
//! ONE [`SharedModel`](crate::engine::SharedModel): the binary/ternary
//! deployment weights are sampled, packed and BN-folded once, and every
//! shard's cell is a clone that aliases the same `Arc`-backed plane
//! allocations (see [`crate::quant::pack`]). Growing the cluster adds
//! slot state and scratch — tens of KB — never plane bytes; that is
//! also what makes [`ServingCluster::add_shard`] cheap enough to call
//! while serving. `rust/tests/cluster_integration.rs` pins this down
//! with `Arc::strong_count` and pointer-identity assertions, and the
//! `serve_cluster` bench reports constant resident weight bytes across
//! shard counts.
//!
//! ## Architecture
//!
//! * **Front door**: clients [`ServingCluster::submit`] (or
//!   [`ServingCluster::try_submit`] for the typed refusal) into a
//!   bounded MPMC queue ([`BoundedQueue`]); a full queue fails fast with
//!   [`SubmitRefused::Full`] (backpressure — "overloaded, retry"), a
//!   draining cluster refuses with [`SubmitRefused::Draining`]
//!   ("shutting down") but completes everything accepted.
//! * **Router**: one async thread pops the front queue and dispatches to
//!   per-shard bounded inboxes under a pluggable [`RoutePolicy`] —
//!   `least-loaded` (default: argmin of in-flight requests) or
//!   `round-robin`. The route table is shared and mutable: shards can be
//!   added and removed while the router runs. A full inbox blocks the
//!   router, propagating pressure back to the front door; a closed inbox
//!   (shard removed, or its worker died) makes the router re-route the
//!   request to a surviving shard — accepted work is never dropped by a
//!   topology change.
//! * **Shard workers**: each owns an `InferenceServer` over a
//!   [`from_shared`] backend and runs the continuous-batching loop —
//!   admit from inbox, step all active slots, emit completions. The
//!   single-server code path IS the 1-shard special case; the cluster
//!   adds routing around it, never a second decode loop. Workers publish
//!   their counters through atomics so [`ServingCluster::live_stats`]
//!   can snapshot a running fleet without stopping it.
//! * **Completions**: per-shard channels merge into one response stream.
//!   In-process callers read it via [`ServingCluster::try_recv`] or let
//!   [`ServingCluster::drain`] collect it; a streaming consumer (the
//!   network front door, [`crate::frontdoor`]) takes ownership of the
//!   receiver with [`ServingCluster::take_responses`] and forwards each
//!   response as it lands.
//!
//! ## Live shard add / remove
//!
//! [`ServingCluster::add_shard`] builds a new engine from the stored
//! [`SharedModel`] (a refcount bump per plane, no byte copies), spawns
//! its worker and publishes it to the route table — new requests start
//! landing on it immediately. [`ServingCluster::remove_shard`] is a
//! graceful per-shard drain: the shard leaves the route table (no new
//! work), its inbox is closed (queued work still drains — a closed
//! [`BoundedQueue`] hands out everything already queued), the worker
//! finishes every admitted request and exits, and its final counters
//! move to the retired list so cluster totals never lose history. The
//! router re-routes any request it was about to place on the removed
//! shard. Zero accepted-request loss in both directions is asserted by
//! `rust/tests/frontdoor_integration.rs` under live load.
//!
//! ## Why shard outputs are bit-identical to a single server
//!
//! A request's trajectory depends only on (a) the packed weights and
//! (b) its own token stream: its slot state is zeroed on admission, the
//! batched/threaded kernels are bit-identical to the per-slot reference
//! for every batch composition and thread count (PR 2/3 invariants), and
//! greedy sampling plus the prompt log-prob are pure functions of the
//! logits. Routing therefore only decides *where* and *when* a request
//! runs, never *what* it computes: for a greedy request set, a cluster
//! with any shard count and either policy — even one whose shard set
//! changes mid-load — produces bit-identical generated tokens and
//! prompt log-probs to one `InferenceServer` — enforced by
//! `cluster_integration.rs` and the `ci.sh` shards=1 vs shards=2 digest
//! diff. (At temperature > 0, sampled tokens depend on each server's
//! rng stream and therefore on scheduling; equivalence is a
//! greedy-decoding guarantee.)

mod queue;
mod stats;

pub use queue::{BoundedQueue, PushRefused};
pub use stats::{ClusterStats, ShardStats};

use std::collections::BTreeMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{InferenceServer, LoadSpec, Request, Response,
                         ServerStats};
use crate::engine::{from_shared, BackendSpec, SharedModel, ThreadPool};
use crate::faults::FaultPlan;
use crate::obs::{EventKind, LogHistogram, Obs};
use crate::session::{prepare_with, PreparedSubmit, ServerSessions,
                     SessionCache, SubmitOpts, DEFAULT_SESSION_BYTES,
                     DEFAULT_SESSION_GRID};
use crate::util::stats::{safe_rate, LatencySummary};

/// How the router assigns requests to engine shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Dispatch to the shard with the fewest in-flight requests
    /// (routed minus completed); ties go to the lowest shard id.
    LeastLoaded,
    /// Dispatch strictly in rotation, ignoring load.
    RoundRobin,
}

impl RoutePolicy {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "least-loaded" | "least_loaded" | "ll" => RoutePolicy::LeastLoaded,
            "round-robin" | "round_robin" | "rr" => RoutePolicy::RoundRobin,
            other => anyhow::bail!(
                "unknown routing policy '{other}' (accepted: least-loaded | \
                 least_loaded | ll, round-robin | round_robin | rr)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::RoundRobin => "round-robin",
        }
    }

    pub fn all() -> [RoutePolicy; 2] {
        [RoutePolicy::LeastLoaded, RoutePolicy::RoundRobin]
    }
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy::LeastLoaded
    }
}

impl std::fmt::Display for RoutePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Why [`ServingCluster::try_submit`] refused a request — the typed
/// split the front door needs to answer "overloaded, retry later"
/// differently from "shutting down" on the wire (mirrors
/// [`PushRefused`], plus validation).
#[derive(Clone, Debug, PartialEq)]
pub enum SubmitRefused {
    /// The bounded front door is at capacity — backpressure; shed load
    /// or retry later. `pending` is the queue depth observed at refusal.
    Full { pending: usize },
    /// The cluster is draining — no new work is accepted (everything
    /// already accepted still completes).
    Draining,
    /// The request failed validation and was never enqueued.
    Invalid(String),
}

impl std::fmt::Display for SubmitRefused {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitRefused::Full { pending } => write!(
                f, "cluster queue full ({pending} pending)"),
            SubmitRefused::Draining => write!(
                f, "cluster is draining; no new requests accepted"),
            SubmitRefused::Invalid(msg) => write!(f, "invalid request: {msg}"),
        }
    }
}

impl std::error::Error for SubmitRefused {}

/// Bounded retry-with-backoff at cluster admission, applied ONLY to
/// [`SubmitRefused::Full`] (transient backpressure): the submit sleeps
/// `backoff`, doubles it (capped at 100 ms) and tries again, up to
/// `attempts` extra tries. `Draining` and `Invalid` refusals are never
/// retried — they cannot succeed later / at all. The default is 0
/// attempts, i.e. today's fail-fast behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetrySpec {
    /// Extra attempts after the first `Full` refusal (0 = fail fast).
    pub attempts: usize,
    /// First backoff sleep; doubles per retry, capped at 100 ms.
    pub backoff: Duration,
}

impl RetrySpec {
    /// Largest per-retry sleep the doubling backoff reaches.
    pub const MAX_BACKOFF: Duration = Duration::from_millis(100);
}

impl Default for RetrySpec {
    fn default() -> Self {
        Self { attempts: 0, backoff: Duration::from_millis(2) }
    }
}

/// What a shard produced for one accepted request: the completed
/// response, or a typed deadline expiry (the request's latency budget
/// ran out while it was still queued — it was never stepped).
#[derive(Clone, Debug)]
pub enum ShardOutcome {
    Done(Response),
    Expired { id: u64 },
}

/// A per-request outcome, tagged with the shard that produced it.
#[derive(Clone, Debug)]
pub struct ClusterResponse {
    pub shard: usize,
    pub outcome: ShardOutcome,
}

impl ClusterResponse {
    /// The request id this outcome answers.
    pub fn id(&self) -> u64 {
        match &self.outcome {
            ShardOutcome::Done(r) => r.id,
            ShardOutcome::Expired { id } => *id,
        }
    }

    /// The completed response, when the outcome is [`ShardOutcome::Done`].
    pub fn done(&self) -> Option<&Response> {
        match &self.outcome {
            ShardOutcome::Done(r) => Some(r),
            ShardOutcome::Expired { .. } => None,
        }
    }

    /// Owning variant of [`Self::done`].
    pub fn into_done(self) -> Option<Response> {
        match self.outcome {
            ShardOutcome::Done(r) => Some(r),
            ShardOutcome::Expired { .. } => None,
        }
    }
}

/// Everything a drained cluster run produced.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Merged response stream (arrival order; sort by id to compare).
    /// Empty when a streaming consumer took the receiver
    /// ([`ServingCluster::take_responses`]) or consumed it via
    /// [`ServingCluster::try_recv`] — the stats still cover every
    /// request either way.
    pub responses: Vec<ClusterResponse>,
    pub stats: ClusterStats,
}

impl ClusterReport {
    pub fn tokens_per_sec(&self) -> f64 {
        self.stats.tokens_per_sec
    }
}

/// What travels through the router: a request already resolved against
/// the session cache ([`PreparedSubmit`]), so restored session state
/// rides along to whichever shard the router picks — resumed sessions
/// are not shard-pinned. `Clone` so a supervised shard can retain
/// in-flight items and re-admit them after a crash.
#[derive(Clone)]
struct Routed {
    ps: PreparedSubmit,
    /// Admission time — queue_time covers the whole cluster path.
    submitted: Instant,
    /// Absolute latency budget; a request still queued past this point
    /// is answered [`ShardOutcome::Expired`] instead of being stepped.
    deadline: Option<Instant>,
}

/// Robustness knobs for [`ServingCluster::new_with_options`]; the other
/// constructors use `Default` (supervision on, no deadline, fail-fast
/// admission, no fault injection).
pub struct ClusterOptions {
    /// Front-door queue capacity (the fail-fast backpressure boundary).
    pub queue_cap: usize,
    pub policy: RoutePolicy,
    /// Contain shard-worker panics and respawn the engine from the
    /// shared model, re-admitting the dead generation's in-flight
    /// requests (see the module docs). Off = a shard panic is fatal to
    /// that shard and surfaces as a typed error from
    /// [`ServingCluster::drain`].
    pub supervise: bool,
    /// Default per-request latency budget, measured from admission
    /// (`None` = no deadline). A per-submit
    /// [`SubmitOpts::deadline`] overrides it.
    pub deadline: Option<Duration>,
    /// Bounded retry-with-backoff for `Full` admission refusals.
    pub retry: RetrySpec,
    /// Deterministic fault-injection plan (tests / chaos gate only;
    /// `None` in production — the hooks are zero-cost when absent).
    pub faults: Option<Arc<FaultPlan>>,
    /// Observability hub (`--trace`); `None` (the default) = tracing
    /// off, every hook is a no-op branch — see [`crate::obs`].
    pub obs: Option<Arc<Obs>>,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        Self {
            queue_cap: 256,
            policy: RoutePolicy::default(),
            supervise: true,
            deadline: None,
            retry: RetrySpec::default(),
            faults: None,
            obs: None,
        }
    }
}

/// One live shard's routing handle, shared with the router through the
/// mutable route table. Cloned Arcs, so the router can hold a pick
/// without holding the table lock across a (possibly blocking) push.
struct RouteEntry {
    id: usize,
    inbox: Arc<BoundedQueue<Routed>>,
    load: Arc<AtomicU64>,
    routed: Arc<AtomicU64>,
}

/// Worker-published serving counters, snapshotted by
/// [`ServingCluster::live_stats`] without stopping the shard.
#[derive(Default)]
struct ShardCounters {
    completed: AtomicU64,
    engine_steps: AtomicU64,
    tokens_processed: AtomicU64,
    peak_active_slots: AtomicU64,
}

impl ShardCounters {
    fn publish(&self, s: &ServerStats) {
        self.completed.store(s.completed, Ordering::SeqCst);
        self.engine_steps.store(s.engine_steps, Ordering::SeqCst);
        self.tokens_processed.store(s.tokens_processed, Ordering::SeqCst);
        self.peak_active_slots
            .store(s.peak_active_slots as u64, Ordering::SeqCst);
    }

    fn snapshot(&self) -> ServerStats {
        ServerStats {
            completed: self.completed.load(Ordering::SeqCst),
            engine_steps: self.engine_steps.load(Ordering::SeqCst),
            tokens_processed: self.tokens_processed.load(Ordering::SeqCst),
            peak_active_slots:
                self.peak_active_slots.load(Ordering::SeqCst) as usize,
        }
    }
}

/// Everything the cluster keeps per live shard.
struct ShardHandle {
    id: usize,
    inbox: Arc<BoundedQueue<Routed>>,
    load: Arc<AtomicU64>,
    routed: Arc<AtomicU64>,
    counters: Arc<ShardCounters>,
    worker: JoinHandle<ServerStats>,
}

impl ShardHandle {
    fn route_entry(&self) -> RouteEntry {
        RouteEntry {
            id: self.id,
            inbox: self.inbox.clone(),
            load: self.load.clone(),
            routed: self.routed.clone(),
        }
    }
}

/// Completion-latency ring (capped so a long-lived serving process does
/// not grow without bound): every completion lands here — streamed or
/// drained, live or retired shard — so the p50/p95/p99 in
/// [`ClusterStats`] always describe the full accepted workload, not
/// just the responses one particular consumer happened to hold.
const LATENCY_LOG_CAP: usize = 65536;

#[derive(Default)]
struct LatencyLog {
    next: usize,
    queue_ms: Vec<f64>,
    run_ms: Vec<f64>,
    total_ms: Vec<f64>,
}

impl LatencyLog {
    fn record(&mut self, queue_ms: f64, run_ms: f64) {
        let total = queue_ms + run_ms;
        if self.queue_ms.len() < LATENCY_LOG_CAP {
            self.queue_ms.push(queue_ms);
            self.run_ms.push(run_ms);
            self.total_ms.push(total);
        } else {
            self.queue_ms[self.next] = queue_ms;
            self.run_ms[self.next] = run_ms;
            self.total_ms[self.next] = total;
        }
        self.next = (self.next + 1) % LATENCY_LOG_CAP;
    }

    fn summaries(&self) -> (LatencySummary, LatencySummary, LatencySummary) {
        (LatencySummary::from_ms(&self.queue_ms),
         LatencySummary::from_ms(&self.run_ms),
         LatencySummary::from_ms(&self.total_ms))
    }

    /// Log-bucketed distributions over the same samples the percentile
    /// summaries cover (works with tracing off — the log always runs).
    fn histograms(&self) -> (LogHistogram, LogHistogram, LogHistogram) {
        let fill = |ms: &[f64]| {
            let mut h = LogHistogram::latency_ms();
            for &v in ms {
                h.observe(v);
            }
            h
        };
        (fill(&self.queue_ms), fill(&self.run_ms), fill(&self.total_ms))
    }
}

/// The sharded serving cluster; see the module docs.
pub struct ServingCluster {
    front: Arc<BoundedQueue<Routed>>,
    table: Arc<Mutex<Vec<RouteEntry>>>,
    router: Option<JoinHandle<()>>,
    shards: Vec<ShardHandle>,
    /// Final counters of removed shards — totals keep their history.
    retired: Vec<ShardStats>,
    done_tx: Option<mpsc::Sender<ClusterResponse>>,
    done_rx: Option<mpsc::Receiver<ClusterResponse>>,
    latency: Arc<Mutex<LatencyLog>>,
    /// The packed template — kept so [`Self::add_shard`] can build new
    /// engines later. A clone of the caller's model: refcount bumps on
    /// the plane `Arc`s, zero byte copies.
    shared: SharedModel,
    shard_spec: BackendSpec,
    inbox_cap: usize,
    next_shard_id: usize,
    vocab: usize,
    slots_per_shard: usize,
    weight_bytes: usize,
    policy: RoutePolicy,
    submitted: u64,
    started: Instant,
    /// The cluster-wide session cache handle (`None` = sessions
    /// disabled; session/resume submits are refused as Invalid).
    sessions: Option<ServerSessions>,
    supervise: bool,
    deadline: Option<Duration>,
    retry: RetrySpec,
    faults: Option<Arc<FaultPlan>>,
    obs: Option<Arc<Obs>>,
    /// `Full` admission refusals absorbed by retry backoff so far.
    retry_attempts: u64,
    /// Shard-worker respawns performed by supervision (fleet-wide).
    respawns: Arc<AtomicU64>,
    /// Requests answered `Expired` instead of served (fleet-wide).
    expired: Arc<AtomicU64>,
}

impl ServingCluster {
    /// Build `spec.shards` engine shards over `shared` (each
    /// [`from_shared`] — zero-copy on the plane bytes) and start the
    /// router + worker threads. `queue_cap` bounds the front door.
    ///
    /// With `spec.threads = 0` (auto), the machine's per-core GEMM
    /// worker budget is divided across the *initial* shard count
    /// (`available / shards` workers each, min 1) so scaling out shards
    /// doesn't oversubscribe the CPU; an explicit thread count applies
    /// to every shard unchanged. Shards added later with
    /// [`Self::add_shard`] reuse the same per-shard budget.
    pub fn new(shared: &SharedModel, spec: &BackendSpec, queue_cap: usize,
               policy: RoutePolicy) -> Result<Self> {
        Self::new_with_sessions(
            shared, spec, queue_cap, policy,
            Some(SessionCache::new(DEFAULT_SESSION_BYTES,
                                   DEFAULT_SESSION_GRID)))
    }

    /// [`Self::new`] with an explicit session cache: pass a sized
    /// [`SessionCache`] to share (or tune) it, or `None` to disable
    /// sessions entirely (session/resume submits are then refused).
    /// [`Self::new`] defaults to an enabled cache of
    /// [`DEFAULT_SESSION_BYTES`] / [`DEFAULT_SESSION_GRID`].
    pub fn new_with_sessions(shared: &SharedModel, spec: &BackendSpec,
                             queue_cap: usize, policy: RoutePolicy,
                             cache: Option<SessionCache>) -> Result<Self> {
        Self::new_with_options(
            shared, spec,
            ClusterOptions { queue_cap, policy, ..Default::default() },
            cache)
    }

    /// The full constructor: every robustness knob ([`ClusterOptions`])
    /// plus the session cache choice of [`Self::new_with_sessions`].
    pub fn new_with_options(shared: &SharedModel, spec: &BackendSpec,
                            opts: ClusterOptions,
                            cache: Option<SessionCache>) -> Result<Self> {
        let ClusterOptions { queue_cap, policy, supervise, deadline,
                             retry, faults, obs } = opts;
        let sessions = cache.map(|c| ServerSessions::new(c, shared));
        if let Some(s) = &sessions {
            s.cache.set_obs(obs.clone());
        }
        let shards = spec.shards;
        anyhow::ensure!(shards >= 1, "need at least one engine shard");
        anyhow::ensure!(shards <= BackendSpec::MAX_SHARDS,
                        "shards {} out of range [1, {}]", shards,
                        BackendSpec::MAX_SHARDS);
        // auto thread budget (threads = 0) is divided across shards:
        // every shard owning a full one-pool-worker-per-core would
        // oversubscribe the machine shards-fold and the sweep would
        // measure contention, not scaling. Explicit counts pass
        // through untouched — oversubscription then is the
        // operator's stated choice.
        let mut shard_spec = *spec;
        if spec.batch_gemm && spec.threads == 0 {
            shard_spec.threads = (ThreadPool::available() / shards).max(1);
        }
        // small bounded inboxes: enough lookahead to refill slots
        // without stalling, small enough that backpressure reaches
        // the router (and through it, the front door) quickly
        let inbox_cap = (2 * spec.slots).max(2);
        // build every shard engine up front so a bad spec fails before
        // any thread exists
        let mut servers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let backend = from_shared(shared, &shard_spec)?;
            let mut server = InferenceServer::with_backend(backend,
                                                           spec.slots.max(1));
            // every shard shares the ONE cache under the one model
            // fingerprint — a prefix published by any shard hits on all
            server.set_sessions(sessions.clone());
            servers.push(server);
        }
        let front: Arc<BoundedQueue<Routed>> =
            Arc::new(BoundedQueue::new(queue_cap));
        let table: Arc<Mutex<Vec<RouteEntry>>> =
            Arc::new(Mutex::new(Vec::with_capacity(shards)));
        let latency = Arc::new(Mutex::new(LatencyLog::default()));
        let (done_tx, done_rx) = mpsc::channel();
        let respawns = Arc::new(AtomicU64::new(0));
        let expired = Arc::new(AtomicU64::new(0));
        let slots = spec.slots.max(1);
        let mut handles: Vec<ShardHandle> = Vec::with_capacity(shards);
        for (id, mut server) in servers.into_iter().enumerate() {
            server.set_obs(obs.clone(), id);
            let ctx = ShardContext {
                inbox_cap,
                latency: latency.clone(),
                done: done_tx.clone(),
                supervise,
                faults: faults.clone(),
                obs: obs.clone(),
                factory: respawn_factory(shared, &shard_spec, slots,
                                         &sessions),
                respawns: respawns.clone(),
                expired: expired.clone(),
            };
            match spawn_shard(id, server, ctx) {
                Ok(h) => {
                    table.lock().unwrap().push(h.route_entry());
                    handles.push(h);
                }
                Err(e) => {
                    for h in &handles {
                        h.inbox.close();
                    }
                    for h in handles {
                        let _ = h.worker.join();
                    }
                    return Err(e);
                }
            }
        }
        let router = {
            let front_r = front.clone();
            let table_r = table.clone();
            let obs_r = obs.clone();
            let spawned = std::thread::Builder::new()
                .name("rbtw-cluster-router".to_string())
                .spawn(move || router_loop(front_r, table_r, policy, obs_r));
            match spawned {
                Ok(h) => h,
                Err(e) => {
                    front.close();
                    for h in &handles {
                        h.inbox.close();
                    }
                    for h in handles {
                        let _ = h.worker.join();
                    }
                    return Err(e).context("spawning the cluster router");
                }
            }
        };
        Ok(Self {
            front,
            table,
            router: Some(router),
            shards: handles,
            retired: vec![],
            done_tx: Some(done_tx),
            done_rx: Some(done_rx),
            latency,
            shared: shared.clone(),
            shard_spec,
            inbox_cap,
            next_shard_id: shards,
            vocab: shared.vocab(),
            slots_per_shard: spec.slots.max(1),
            weight_bytes: shared.weight_bytes(),
            policy,
            submitted: 0,
            started: Instant::now(),
            sessions,
            supervise,
            deadline,
            retry,
            faults,
            obs,
            retry_attempts: 0,
            respawns,
            expired,
        })
    }

    /// The cluster-wide session cache handle, if sessions are enabled.
    pub fn sessions(&self) -> Option<&ServerSessions> {
        self.sessions.as_ref()
    }

    /// Whether shard-worker panics are contained and respawned.
    pub fn supervised(&self) -> bool {
        self.supervise
    }

    /// The default per-request latency budget, if any.
    pub fn default_deadline(&self) -> Option<Duration> {
        self.deadline
    }

    /// The admission retry policy for `Full` refusals.
    pub fn retry(&self) -> RetrySpec {
        self.retry
    }

    /// The active fault-injection plan, if any (chaos harness).
    pub fn faults(&self) -> Option<Arc<FaultPlan>> {
        self.faults.clone()
    }

    /// The observability hub, if tracing is on (see [`crate::obs`]).
    pub fn obs(&self) -> Option<Arc<Obs>> {
        self.obs.clone()
    }

    /// `Full` admission refusals absorbed by retry backoff so far.
    pub fn retry_attempts(&self) -> u64 {
        self.retry_attempts
    }

    /// Verified integrity fingerprint of the packed serving bits (see
    /// [`SharedModel::fingerprint`]).
    pub fn fingerprint(&self) -> u64 {
        self.shared.fingerprint()
    }

    /// Shard respawns performed by supervision so far.
    pub fn respawn_count(&self) -> u64 {
        self.respawns.load(Ordering::SeqCst)
    }

    /// Requests answered `Expired` instead of served so far.
    pub fn expired_count(&self) -> u64 {
        self.expired.load(Ordering::SeqCst)
    }

    /// Live shard count (changes under [`Self::add_shard`] /
    /// [`Self::remove_shard`]).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Ids of the live shards, ascending. Retired ids are never reused.
    pub fn shard_ids(&self) -> Vec<usize> {
        self.shards.iter().map(|h| h.id).collect()
    }

    pub fn slots_per_shard(&self) -> usize {
        self.slots_per_shard
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Resident serving bytes — the ONE shared copy of packed planes +
    /// dense head. Constant in the shard count by construction.
    pub fn weight_bytes(&self) -> usize {
        self.weight_bytes
    }

    /// Front-door queue capacity (the fail-fast backpressure boundary).
    pub fn queue_capacity(&self) -> usize {
        self.front.capacity()
    }

    /// Requests waiting at the front door (not yet routed to a shard).
    pub fn pending(&self) -> usize {
        self.front.len()
    }

    /// Requests accepted so far.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Whether intake has been closed ([`Self::close_intake`] or a
    /// [`Self::drain`] in progress); accepted work still completes.
    pub fn is_draining(&self) -> bool {
        self.front.is_closed()
    }

    /// Stop accepting new requests without tearing anything down — the
    /// first half of a graceful shutdown, split out so a network front
    /// door can refuse clients with "draining" while the fleet finishes
    /// the accepted backlog.
    pub fn close_intake(&self) {
        self.front.close();
    }

    /// Enqueue a request at the front door with a typed refusal. Fails
    /// fast — without touching any shard — when the bounded queue is
    /// full ([`SubmitRefused::Full`]) or the cluster is draining
    /// ([`SubmitRefused::Draining`]). Validation runs here, through the
    /// same [`validate_request`] the shard servers apply, so a
    /// cluster-accepted request can never be one a shard rejects.
    pub fn try_submit(&mut self, req: Request)
        -> std::result::Result<(), SubmitRefused> {
        self.try_submit_with(req, &SubmitOpts::default())
    }

    /// [`Self::try_submit`] with session options: save the final state
    /// under a session id, and/or resume a saved session (the prompt is
    /// then the continuation). Resolution against the session cache
    /// happens HERE, at cluster admission, so restored state travels
    /// inside the routed item to whichever shard the router picks — a
    /// resumed session is not pinned to the shard that suspended it.
    pub fn try_submit_with(&mut self, req: Request, opts: &SubmitOpts)
        -> std::result::Result<(), SubmitRefused> {
        let rid = req.id;
        let ps = match prepare_with(self.sessions.as_ref(), self.vocab,
                                    req, opts) {
            Ok(ps) => ps,
            Err(e) => {
                if let Some(obs) = &self.obs {
                    obs.event(rid, EventKind::Refused { reason: "invalid" });
                }
                return Err(SubmitRefused::Invalid(format!("{e:#}")));
            }
        };
        let now = Instant::now();
        let budget = opts.deadline.or(self.deadline);
        let mut item = Routed {
            ps,
            submitted: now,
            deadline: budget.map(|d| now + d),
        };
        // `Full` is transient backpressure: retry with doubling backoff
        // up to the configured attempts. `Closed` (draining) is final —
        // waiting cannot make a draining cluster accept, so it is never
        // retried.
        let mut backoff = self.retry.backoff;
        let mut tries = 0usize;
        loop {
            match self.front.try_push(item) {
                Ok(()) => {
                    self.submitted += 1;
                    if let Some(obs) = &self.obs {
                        obs.event(rid, EventKind::Admitted);
                    }
                    return Ok(());
                }
                Err((_, PushRefused::Closed)) => {
                    if let Some(obs) = &self.obs {
                        obs.event(rid,
                                  EventKind::Refused { reason: "draining" });
                    }
                    return Err(SubmitRefused::Draining);
                }
                Err((refused, PushRefused::Full)) => {
                    if tries >= self.retry.attempts {
                        if let Some(obs) = &self.obs {
                            obs.event(rid,
                                      EventKind::Refused { reason: "full" });
                        }
                        return Err(SubmitRefused::Full {
                            pending: self.front.len(),
                        });
                    }
                    tries += 1;
                    self.retry_attempts += 1;
                    if let Some(obs) = &self.obs {
                        obs.event(rid, EventKind::Retry {
                            attempt: tries as u32 });
                    }
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(RetrySpec::MAX_BACKOFF);
                    item = refused;
                }
            }
        }
    }

    /// [`Self::try_submit`] with the refusal flattened into an error —
    /// the in-process convenience surface.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        self.try_submit(req).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Non-blocking read of the merged response stream. Responses taken
    /// here (streaming mode) are not repeated in [`Self::drain`]'s
    /// report. Returns `None` once [`Self::take_responses`] has claimed
    /// the stream.
    pub fn try_recv(&self) -> Option<ClusterResponse> {
        self.done_rx.as_ref().and_then(|rx| rx.try_recv().ok())
    }

    /// Take ownership of the merged response stream — the streaming
    /// consumer surface ([`crate::frontdoor`]'s pump thread). The
    /// receiver disconnects after the last accepted response once the
    /// cluster drains. Can be taken at most once.
    pub fn take_responses(&mut self) -> Result<mpsc::Receiver<ClusterResponse>> {
        self.done_rx.take().context("cluster response stream already taken")
    }

    /// Add one engine shard to the live fleet and return its id. Cheap:
    /// the engine is built [`from_shared`], so the new shard aliases the
    /// existing plane allocation (refcount bump, no weight copy). The
    /// router starts dispatching to it as soon as it enters the route
    /// table.
    pub fn add_shard(&mut self) -> Result<usize> {
        anyhow::ensure!(!self.front.is_closed(),
                        "cluster is draining; cannot add a shard");
        anyhow::ensure!(self.shards.len() < BackendSpec::MAX_SHARDS,
                        "cluster already at {} shards (max {})",
                        self.shards.len(), BackendSpec::MAX_SHARDS);
        let backend = from_shared(&self.shared, &self.shard_spec)?;
        let mut server = InferenceServer::with_backend(backend,
                                                       self.slots_per_shard);
        server.set_sessions(self.sessions.clone());
        let done = self.done_tx.as_ref()
            .context("cluster response channel gone")?
            .clone();
        let id = self.next_shard_id;
        server.set_obs(self.obs.clone(), id);
        let ctx = ShardContext {
            inbox_cap: self.inbox_cap,
            latency: self.latency.clone(),
            done,
            supervise: self.supervise,
            faults: self.faults.clone(),
            obs: self.obs.clone(),
            factory: respawn_factory(&self.shared, &self.shard_spec,
                                     self.slots_per_shard, &self.sessions),
            respawns: self.respawns.clone(),
            expired: self.expired.clone(),
        };
        let h = spawn_shard(id, server, ctx)?;
        self.next_shard_id += 1;
        self.table.lock().unwrap().push(h.route_entry());
        self.shards.push(h);
        Ok(id)
    }

    /// Gracefully remove shard `id` from the live fleet: it leaves the
    /// route table (no new work), its inbox closes (everything already
    /// queued still drains), the worker finishes every admitted request
    /// and exits, and its final counters are returned and retained in
    /// the retired list. The router re-routes any request it was about
    /// to place here, so a removal never drops accepted work. Refuses
    /// to remove the last live shard.
    pub fn remove_shard(&mut self, id: usize) -> Result<ShardStats> {
        anyhow::ensure!(self.shards.len() > 1,
                        "cannot remove the last live shard ({id})");
        let pos = self.shards.iter().position(|h| h.id == id)
            .with_context(|| format!("no live shard {id} (live: {:?})",
                                     self.shard_ids()))?;
        {
            let mut t = self.table.lock().unwrap();
            if let Some(tp) = t.iter().position(|e| e.id == id) {
                t.remove(tp);
            }
        }
        let h = self.shards.remove(pos);
        h.inbox.close();
        let server = h.worker.join().map_err(
            |_| anyhow::anyhow!("shard {id} worker panicked during removal"))?;
        let wall_s = self.started.elapsed().as_secs_f64();
        let row = ShardStats {
            shard: id,
            routed: h.routed.load(Ordering::SeqCst),
            tokens_per_sec: safe_rate(server.tokens_processed as f64,
                                      wall_s),
            server,
            retired: true,
        };
        self.retired.push(row.clone());
        Ok(row)
    }

    /// Snapshot the running fleet's stats without stopping it: per-shard
    /// counters from the workers' published atomics (retired shards keep
    /// their final numbers), latency percentiles over every completion
    /// so far, throughput over the wall clock so far.
    pub fn live_stats(&self) -> ClusterStats {
        let rows = self.shards.iter().map(|h| ShardStats {
            shard: h.id,
            routed: h.routed.load(Ordering::SeqCst),
            tokens_per_sec: 0.0, // filled against the wall clock below
            server: h.counters.snapshot(),
            retired: false,
        }).collect();
        self.assemble_stats(rows)
    }

    /// Graceful shutdown: stop intake, let every accepted request finish
    /// (router drains the front queue, shards drain their inboxes and
    /// slots), join all threads, and return the merged responses plus
    /// aggregated [`ClusterStats`].
    ///
    /// Responses already consumed via [`Self::try_recv`] — or streamed
    /// through a receiver claimed by [`Self::take_responses`] — are not
    /// repeated in the report, but every counter and latency percentile
    /// still covers the full accepted workload (completions are
    /// recorded at the shard, not at the consumer).
    pub fn drain(mut self) -> Result<ClusterReport> {
        self.front.close();
        // drop our sender so the stream disconnects exactly when the
        // last worker exits — i.e. when all accepted work has completed
        drop(self.done_tx.take());
        let mut responses = vec![];
        if let Some(rx) = self.done_rx.take() {
            while let Ok(r) = rx.recv() {
                responses.push(r);
            }
        }
        if let Some(h) = self.router.take() {
            h.join()
                .map_err(|_| anyhow::anyhow!("cluster router panicked"))?;
        }
        let mut rows = vec![];
        let mut panicked = vec![];
        for h in std::mem::take(&mut self.shards) {
            let id = h.id;
            let routed = h.routed.load(Ordering::SeqCst);
            match h.worker.join() {
                Ok(server) => rows.push(ShardStats {
                    shard: id,
                    routed,
                    tokens_per_sec: 0.0, // filled in assemble_stats
                    server,
                    retired: false,
                }),
                Err(_) => panicked.push(id),
            }
        }
        anyhow::ensure!(panicked.is_empty(),
                        "cluster shard worker(s) {panicked:?} panicked");
        let stats = self.assemble_stats(rows);
        Ok(ClusterReport { responses, stats })
    }

    /// Fold live/final shard rows + retired history into [`ClusterStats`]
    /// against the shared wall clock and the full completion-latency log.
    fn assemble_stats(&self, rows: Vec<ShardStats>) -> ClusterStats {
        let wall_s = self.started.elapsed().as_secs_f64();
        let (queue, run, total, queue_hist, run_hist, total_hist) = {
            let log = self.latency.lock().unwrap();
            let (q, r, t) = log.summaries();
            let (qh, rh, th) = log.histograms();
            (q, r, t, qh, rh, th)
        };
        let mut stats = ClusterStats { wall_s, queue, run, total,
                                       queue_hist, run_hist, total_hist,
                                       ..ClusterStats::default() };
        let mut all = self.retired.clone();
        all.extend(rows);
        all.sort_by_key(|s| s.shard);
        for mut row in all {
            row.tokens_per_sec =
                safe_rate(row.server.tokens_processed as f64, wall_s);
            stats.completed += row.server.completed;
            stats.tokens_processed += row.server.tokens_processed;
            stats.engine_steps += row.server.engine_steps;
            stats.shards.push(row);
        }
        stats.tokens_per_sec =
            safe_rate(stats.tokens_processed as f64, wall_s);
        stats.sessions = self.sessions.as_ref().map(|s| s.cache.counters());
        stats.respawns = self.respawns.load(Ordering::SeqCst);
        stats.expired = self.expired.load(Ordering::SeqCst);
        stats.retry_attempts = self.retry_attempts;
        stats.stages = self.obs.as_ref()
            .map(|o| o.stage_snapshots())
            .unwrap_or_default();
        stats
    }
}

impl Drop for ServingCluster {
    /// Dropping without [`Self::drain`] still shuts down gracefully:
    /// close the front door and wait for the fleet (accepted work
    /// completes; its responses are discarded with the channel).
    fn drop(&mut self) {
        self.front.close();
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
        for h in std::mem::take(&mut self.shards) {
            let _ = h.worker.join();
        }
    }
}

/// Everything a shard worker needs beyond its server: channels,
/// counters, and the supervision machinery (respawn factory + fault
/// hooks).
struct ShardContext {
    inbox_cap: usize,
    latency: Arc<Mutex<LatencyLog>>,
    done: mpsc::Sender<ClusterResponse>,
    supervise: bool,
    faults: Option<Arc<FaultPlan>>,
    obs: Option<Arc<Obs>>,
    /// Builds a replacement engine after a contained panic: a
    /// [`from_shared`] clone — plane-`Arc` refcount bump, no weight
    /// copy — sharing the same session cache.
    factory: Box<dyn Fn() -> Result<InferenceServer> + Send>,
    respawns: Arc<AtomicU64>,
    expired: Arc<AtomicU64>,
}

/// The respawn closure handed to every shard: captures cheap clones of
/// the shared model (refcount bumps) and rebuilds an identical engine.
fn respawn_factory(shared: &SharedModel, spec: &BackendSpec, slots: usize,
                   sessions: &Option<ServerSessions>)
    -> Box<dyn Fn() -> Result<InferenceServer> + Send> {
    let shared = shared.clone();
    let spec = *spec;
    let sessions = sessions.clone();
    Box::new(move || {
        let backend = from_shared(&shared, &spec)?;
        let mut server = InferenceServer::with_backend(backend, slots);
        server.set_sessions(sessions.clone());
        Ok(server)
    })
}

/// Spawn one shard worker over its freshly built server; returns the
/// cluster-side handle. Shared by construction and [`ServingCluster::add_shard`].
fn spawn_shard(id: usize, server: InferenceServer, ctx: ShardContext)
    -> Result<ShardHandle> {
    let inbox: Arc<BoundedQueue<Routed>> =
        Arc::new(BoundedQueue::new(ctx.inbox_cap));
    let load = Arc::new(AtomicU64::new(0));
    let routed = Arc::new(AtomicU64::new(0));
    let counters = Arc::new(ShardCounters::default());
    let worker = {
        let inbox = inbox.clone();
        let load = load.clone();
        let counters = counters.clone();
        std::thread::Builder::new()
            .name(format!("rbtw-cluster-shard-{id}"))
            .spawn(move || shard_worker(id, server, inbox, load, counters,
                                        ctx))
            .context("spawning a cluster shard worker")?
    };
    Ok(ShardHandle { id, inbox, load, routed, counters, worker })
}

fn router_loop(front: Arc<BoundedQueue<Routed>>,
               table: Arc<Mutex<Vec<RouteEntry>>>, policy: RoutePolicy,
               obs: Option<Arc<Obs>>) {
    let mut rr = 0usize;
    while let Some(first) = front.pop_wait() {
        let mut item = first;
        loop {
            // pick under the table lock, push outside it: push_wait can
            // block on a full inbox, and a held lock would stall
            // add_shard/remove_shard (and live_stats) behind it
            let picked = {
                let t = table.lock().unwrap();
                if t.is_empty() {
                    None
                } else {
                    let idx = match policy {
                        RoutePolicy::RoundRobin => {
                            let i = rr % t.len();
                            rr += 1;
                            i
                        }
                        RoutePolicy::LeastLoaded => {
                            let mut best = 0usize;
                            let mut best_load = u64::MAX;
                            for (i, e) in t.iter().enumerate() {
                                let v = e.load.load(Ordering::SeqCst);
                                if v < best_load {
                                    best = i;
                                    best_load = v;
                                }
                            }
                            best
                        }
                    };
                    let e = &t[idx];
                    Some((e.id, e.inbox.clone(), e.load.clone(),
                          e.routed.clone()))
                }
            };
            let Some((id, inbox, load, routed)) = picked else {
                // no live shard left (teardown, or every worker died):
                // the request is shed; a dead fleet additionally
                // surfaces as join errors from drain()
                break;
            };
            load.fetch_add(1, Ordering::SeqCst);
            routed.fetch_add(1, Ordering::SeqCst);
            let rid = item.ps.req.id;
            // a full inbox blocks here — pressure propagates to the
            // front door, which is where submit() fails fast
            match inbox.push_wait(item) {
                Ok(()) => {
                    if let Some(obs) = &obs {
                        obs.event(rid, EventKind::Routed { shard: id });
                    }
                    break;
                }
                Err(refused) => {
                    // inbox closed under us: the shard was removed, or
                    // its worker died (the exit guard closes its inbox
                    // so this router can never block on a dead shard).
                    // Drop the stale route and retry on the survivors —
                    // accepted work is re-routed, not shed.
                    load.fetch_sub(1, Ordering::SeqCst);
                    routed.fetch_sub(1, Ordering::SeqCst);
                    let mut t = table.lock().unwrap();
                    if let Some(p) = t.iter().position(|e| e.id == id) {
                        t.remove(p);
                    }
                    drop(t);
                    item = refused;
                }
            }
        }
    }
    // intake closed and fully routed: signal every live shard to
    // finish + exit
    for e in table.lock().unwrap().iter() {
        e.inbox.close();
    }
}

/// Closes a shard's inbox when its worker exits — HOWEVER it exits. A
/// panicking worker must not leave an open inbox behind: the router
/// would eventually block forever in `push_wait` on it, never close the
/// other shards' inboxes, and wedge the whole cluster (drain() and Drop
/// included). With the guard, the router's push simply fails, the
/// request is re-routed to a surviving shard, and the panic surfaces
/// from drain()'s join.
struct InboxCloser(Arc<BoundedQueue<Routed>>);

impl Drop for InboxCloser {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// One engine shard: a supervisor shell around the continuous-batching
/// serve loop. The loop runs panic-contained (`catch_unwind`); on a
/// clean exit (inbox closed AND every admitted request completed) the
/// final stats are returned. On a panic with supervision enabled, the
/// dead engine is rebuilt from the shared model via the respawn factory
/// (the broken stack's plane `Arc`s were released during the unwind, so
/// the plane-owner invariant holds) and the generation's in-flight
/// requests are re-admitted from the retention map — greedy decode is
/// deterministic, so the replay is bit-identical. With supervision off
/// the panic propagates and the shard dies as before (its exit guard
/// still closes the inbox so the router re-routes queued work).
fn shard_worker(shard: usize, server: InferenceServer,
                inbox: Arc<BoundedQueue<Routed>>, load: Arc<AtomicU64>,
                counters: Arc<ShardCounters>,
                ctx: ShardContext) -> ServerStats {
    let _closer = InboxCloser(inbox.clone());
    // Admitted-but-uncompleted requests, keyed by request id (in-flight
    // ids are unique: the front door allocates them, and the in-process
    // harnesses never reuse an id while it is live). An entry is
    // inserted at admission and removed when its completion is drained,
    // so after a panic the map holds exactly the work the dead
    // generation still owed.
    let mut retained: BTreeMap<u64, Routed> = BTreeMap::new();
    // Counter totals finalized by dead generations (a fresh engine
    // restarts its ServerStats at zero; published totals must not go
    // backwards). Replayed work is re-counted by the new generation —
    // crash accounting is monotonic, not exactly-once.
    let mut base = ServerStats::default();
    let mut steps: u64 = 0;
    let mut generation: u64 = 0;
    let mut server = Some(server);
    loop {
        let mut srv = server.take().expect("serve generation owns a server");
        let result = catch_unwind(AssertUnwindSafe(|| {
            let stats = serve_generation(shard, &mut srv, &mut retained,
                                         &mut steps, &inbox, &load,
                                         &counters, &ctx, &base);
            (srv, stats)
        }));
        match result {
            Ok((_srv, stats)) => return stats,
            Err(payload) => {
                if !ctx.supervise {
                    resume_unwind(payload);
                }
                // the last published snapshot is the dead generation's
                // final word; fold it into the base so totals only grow
                base = counters.snapshot();
                ctx.respawns.fetch_add(1, Ordering::SeqCst);
                generation += 1;
                if let Some(obs) = &ctx.obs {
                    obs.event(0, EventKind::Respawn { shard, generation });
                }
                let mut rebuilt = None;
                for attempt in 0u32..8 {
                    match (ctx.factory)() {
                        Ok(mut s) => {
                            s.set_obs(ctx.obs.clone(), shard);
                            rebuilt = Some(s);
                            break;
                        }
                        Err(_) if attempt + 1 < 8 => std::thread::sleep(
                            Duration::from_millis(5 << attempt)),
                        Err(e) => panic!(
                            "shard {shard} respawn failed after 8 \
                             attempts: {e:#}"),
                    }
                }
                server = rebuilt;
            }
        }
    }
}

/// Fold a generation's live stats into the published counters on top of
/// the totals its dead predecessors finalized.
fn publish_totals(counters: &ShardCounters, base: &ServerStats,
                  live: &ServerStats) {
    counters.publish(&ServerStats {
        completed: base.completed + live.completed,
        engine_steps: base.engine_steps + live.engine_steps,
        tokens_processed: base.tokens_processed + live.tokens_processed,
        peak_active_slots: base.peak_active_slots.max(live.peak_active_slots),
    });
}

/// Admit one routed request into the serve loop. An expired deadline is
/// answered `Expired` without ever touching a slot; `replayed` items
/// (re-admitted after a crash) skip the deadline check — they were
/// already accepted and started, and the zero-loss guarantee outranks
/// the latency budget.
#[allow(clippy::too_many_arguments)]
fn admit(shard: usize, server: &mut InferenceServer,
         retained: &mut BTreeMap<u64, Routed>, load: &AtomicU64,
         ctx: &ShardContext, r: Routed, replayed: bool) {
    if !replayed {
        if let Some(obs) = &ctx.obs {
            obs.event(r.ps.req.id, EventKind::Dequeued { shard });
        }
        if let Some(dl) = r.deadline {
            if Instant::now() >= dl {
                load.fetch_sub(1, Ordering::SeqCst);
                ctx.expired.fetch_add(1, Ordering::SeqCst);
                if let Some(obs) = &ctx.obs {
                    obs.event(r.ps.req.id, EventKind::Expired { shard });
                }
                let _ = ctx.done.send(ClusterResponse {
                    shard,
                    outcome: ShardOutcome::Expired { id: r.ps.req.id },
                });
                return;
            }
        }
        retained.insert(r.ps.req.id, r.clone());
    }
    server
        .submit_prepared(r.ps, r.submitted)
        .expect("cluster-validated request rejected by shard");
}

/// One serve generation: the continuous-batching loop over a private
/// `InferenceServer`, fed first from the crash-replay queue, then from
/// the shard inbox. Returns the lifetime stats (base + this generation)
/// when the inbox is closed AND every admitted request has completed.
#[allow(clippy::too_many_arguments)]
fn serve_generation(shard: usize, server: &mut InferenceServer,
                    retained: &mut BTreeMap<u64, Routed>, steps: &mut u64,
                    inbox: &Arc<BoundedQueue<Routed>>, load: &AtomicU64,
                    counters: &ShardCounters, ctx: &ShardContext,
                    base: &ServerStats) -> ServerStats {
    // Work a dead predecessor still owed, replayed in admission order
    // (in-flight can exceed the server queue capacity, so items feed
    // through the same top-up loop as fresh work instead of being
    // submitted all at once).
    let mut replay: Vec<Routed> = retained.values().cloned().collect();
    replay.sort_by_key(|r| r.submitted);
    let mut replay = std::collections::VecDeque::from(replay);
    loop {
        // top up the admission queue without blocking while there is
        // runnable work
        while server.pending() < server.queue_capacity() {
            if let Some(r) = replay.pop_front() {
                admit(shard, server, retained, load, ctx, r, true);
            } else if let Some(r) = inbox.try_pop() {
                admit(shard, server, retained, load, ctx, r, false);
            } else {
                break;
            }
        }
        if server.pending() == 0 && server.active() == 0 {
            // idle: block for work, or exit once the inbox is closed
            // and drained (replay is empty here — a non-empty replay
            // always leaves pending work above)
            match inbox.pop_wait() {
                Some(r) => {
                    admit(shard, server, retained, load, ctx, r, false);
                    continue;
                }
                None => break,
            }
        }
        *steps += 1;
        if let Some(f) = &ctx.faults {
            if f.shard_panic_due(shard, *steps) {
                panic!("fault injection: shard {shard} panicking at engine \
                        step {steps}");
            }
        }
        server.step().expect("engine step failed on a validated batch");
        while let Ok(resp) = server.done_rx.try_recv() {
            retained.remove(&resp.id);
            load.fetch_sub(1, Ordering::SeqCst);
            ctx.latency.lock().unwrap().record(
                resp.queue_time.as_secs_f64() * 1e3,
                resp.run_time.as_secs_f64() * 1e3);
            // a gone collector is not an error mid-teardown; keep
            // stepping so accepted work still runs to completion
            let _ = ctx.done.send(ClusterResponse {
                shard,
                outcome: ShardOutcome::Done(resp),
            });
        }
        publish_totals(counters, base, &server.stats);
    }
    publish_totals(counters, base, &server.stats);
    ServerStats {
        completed: base.completed + server.stats.completed,
        engine_steps: base.engine_steps + server.stats.engine_steps,
        tokens_processed: base.tokens_processed
            + server.stats.tokens_processed,
        peak_active_slots: base.peak_active_slots
            .max(server.stats.peak_active_slots),
    }
}

/// Drive `load` through a fresh cluster over `shared` — the cluster twin
/// of [`crate::coordinator::run_load`]. Uses [`LoadSpec::requests`], so
/// the request set is byte-identical to the single-server harness for
/// the same spec (the basis of the shards=N equivalence checks).
/// `queue_cap` sizes the front door; the whole load is submitted up
/// front, so pass at least `load.n_requests` (it is clamped up to that)
/// unless the point is to exercise rejection.
pub fn run_cluster_load(shared: &SharedModel, spec: &BackendSpec,
                        policy: RoutePolicy, queue_cap: usize,
                        load: &LoadSpec) -> Result<ClusterReport> {
    let mut cluster = ServingCluster::new(
        shared, spec, queue_cap.max(load.n_requests).max(1), policy)?;
    for req in load.requests(cluster.vocab()) {
        cluster.submit(req)?;
    }
    cluster.drain()
}

/// [`run_cluster_load`] with full [`ClusterOptions`]: the same
/// byte-identical request set over a cluster with tracing,
/// supervision, deadlines or fault plans armed. The obs-equivalence
/// gates drive tracing through this (`opts.queue_cap` is clamped up to
/// the load size, matching [`run_cluster_load`]).
pub fn run_cluster_load_with(shared: &SharedModel, spec: &BackendSpec,
                             mut opts: ClusterOptions, load: &LoadSpec)
    -> Result<ClusterReport> {
    opts.queue_cap = opts.queue_cap.max(load.n_requests).max(1);
    let mut cluster =
        ServingCluster::new_with_options(shared, spec, opts, None)?;
    for req in load.requests(cluster.vocab()) {
        cluster.submit(req)?;
    }
    cluster.drain()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{BackendKind, ModelWeights};

    fn shared_model() -> SharedModel {
        let w = ModelWeights::synthetic(20, 12, "ter", 0xC1);
        SharedModel::prepare(&w, BackendKind::PackedCpu, 7).unwrap()
    }

    fn greedy(id: u64) -> Request {
        Request {
            id,
            prompt: vec![(id % 20) as i32, 3],
            gen_len: 3,
            temperature: 0.0,
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in RoutePolicy::all() {
            assert_eq!(RoutePolicy::parse(p.label()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("ll").unwrap(),
                   RoutePolicy::LeastLoaded);
        assert_eq!(RoutePolicy::default(), RoutePolicy::LeastLoaded);
        assert!(RoutePolicy::parse("random").is_err());
    }

    #[test]
    fn policy_parse_error_lists_every_accepted_spelling() {
        let err = format!("{:#}", RoutePolicy::parse("random").unwrap_err());
        for spelling in ["least-loaded", "least_loaded", "ll",
                         "round-robin", "round_robin", "rr"] {
            assert!(err.contains(spelling),
                    "parse error must list '{spelling}': {err}");
        }
    }

    #[test]
    fn serves_and_drains_all_requests() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 3, 7)
            .with_shards(2);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 32, RoutePolicy::LeastLoaded)
                .unwrap();
        assert_eq!(cluster.shards(), 2);
        assert_eq!(cluster.shard_ids(), vec![0, 1]);
        assert_eq!(cluster.weight_bytes(), shared.weight_bytes());
        for id in 0..10u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        let report = cluster.drain().unwrap();
        assert_eq!(report.responses.len(), 10);
        let mut ids: Vec<u64> =
            report.responses.iter().map(|r| r.id()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10, "every request completed exactly once");
        assert_eq!(report.stats.completed, 10);
        let routed_total: u64 =
            report.stats.shards.iter().map(|s| s.routed).sum();
        assert_eq!(routed_total, 10, "router accounted every request");
        assert_eq!(report.stats.shards.len(), 2);
        assert_eq!(report.stats.total.n, 10);
        assert!(report.stats.tokens_per_sec > 0.0);
        assert_eq!(report.stats.respawns, 0);
        assert_eq!(report.stats.expired, 0);
        for r in &report.responses {
            assert!(r.shard < 2);
            let resp = r.done().expect("no deadline => every outcome Done");
            assert_eq!(resp.generated.len(), 3);
            assert!(resp.prompt_logprob <= 0.0);
        }
    }

    #[test]
    fn submit_validates_before_routing() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 8, RoutePolicy::RoundRobin)
                .unwrap();
        assert!(cluster.submit(Request { id: 1, prompt: vec![],
                                         gen_len: 1, temperature: 0.0 })
            .is_err());
        assert!(cluster.submit(Request { id: 2, prompt: vec![99],
                                         gen_len: 1, temperature: 0.0 })
            .is_err());
        assert_eq!(cluster.submitted(), 0);
        let report = cluster.drain().unwrap();
        assert!(report.responses.is_empty());
        assert_eq!(report.stats.completed, 0);
    }

    #[test]
    fn try_submit_reports_typed_refusals() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 1, 7);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 1, RoutePolicy::LeastLoaded)
                .unwrap();
        // invalid request: typed, never enqueued
        let refused = cluster
            .try_submit(Request { id: 0, prompt: vec![], gen_len: 1,
                                  temperature: 0.0 })
            .unwrap_err();
        assert!(matches!(refused, SubmitRefused::Invalid(_)));
        assert_eq!(cluster.submitted(), 0);
        // overload: keep pushing until the bounded pipeline refuses —
        // the refusal must be Full (backpressure), never Draining
        let mut saw_full = false;
        for id in 0..2000u64 {
            match cluster.try_submit(Request { id, prompt: vec![1],
                                               gen_len: 512,
                                               temperature: 0.0 }) {
                Ok(()) => {}
                Err(SubmitRefused::Full { pending }) => {
                    assert!(pending >= 1);
                    saw_full = true;
                    break;
                }
                Err(other) => panic!("expected Full, got {other:?}"),
            }
        }
        assert!(saw_full, "bounded front door never refused");
        // draining: typed as Draining, distinct from Full
        cluster.close_intake();
        assert!(cluster.is_draining());
        let refused = cluster.try_submit(greedy(9999)).unwrap_err();
        assert_eq!(refused, SubmitRefused::Draining);
        let accepted = cluster.submitted();
        let report = cluster.drain().unwrap();
        assert_eq!(report.stats.completed, accepted,
                   "every accepted request completed despite refusals");
    }

    #[test]
    fn add_and_remove_shards_while_serving() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 64, RoutePolicy::RoundRobin)
                .unwrap();
        assert_eq!(cluster.shard_ids(), vec![0]);
        for id in 0..8u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        let new_id = cluster.add_shard().unwrap();
        assert_eq!(new_id, 1);
        assert_eq!(cluster.shard_ids(), vec![0, 1]);
        for id in 8..16u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        // graceful removal mid-load: shard 0 finishes its admitted work
        let row = cluster.remove_shard(0).unwrap();
        assert!(row.retired);
        assert_eq!(row.shard, 0);
        assert_eq!(cluster.shard_ids(), vec![1]);
        // the last live shard is protected
        assert!(cluster.remove_shard(1).is_err());
        // unknown ids are reported, not ignored
        assert!(cluster.remove_shard(42).is_err());
        for id in 16..20u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        let live = cluster.live_stats();
        assert_eq!(live.shards.len(), 2, "retired + live rows");
        assert!(live.shards.iter().any(|s| s.retired && s.shard == 0));
        assert!(live.shards.iter().any(|s| !s.retired && s.shard == 1));
        let report = cluster.drain().unwrap();
        assert_eq!(report.responses.len(), 20,
                   "zero accepted-request loss across add+remove");
        let mut ids: Vec<u64> =
            report.responses.iter().map(|r| r.id()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20);
        assert_eq!(report.stats.completed, 20,
                   "retired shard history kept in the totals");
        let routed_total: u64 =
            report.stats.shards.iter().map(|s| s.routed).sum();
        assert_eq!(routed_total, 20);
    }

    #[test]
    fn take_responses_streams_while_stats_stay_complete() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7)
            .with_shards(2);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 16, RoutePolicy::LeastLoaded)
                .unwrap();
        let rx = cluster.take_responses().unwrap();
        assert!(cluster.take_responses().is_err(), "stream taken once");
        assert!(cluster.try_recv().is_none());
        for id in 0..6u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        let collector = std::thread::spawn(move || {
            let mut got = vec![];
            while let Ok(r) = rx.recv() {
                got.push(r);
            }
            got
        });
        let report = cluster.drain().unwrap();
        let streamed = collector.join().unwrap();
        assert!(report.responses.is_empty(),
                "streaming consumer owns the responses");
        assert_eq!(streamed.len(), 6);
        assert_eq!(report.stats.completed, 6);
        assert_eq!(report.stats.total.n, 6,
                   "latency percentiles cover streamed completions");
    }

    #[test]
    fn instant_drain_reports_clean_zeroed_stats() {
        // regression: a drain before any submit used to risk a
        // percentile panic (empty samples) and inf/NaN rates (elapsed
        // time ~ 0); it must report zeros.
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7)
            .with_shards(2);
        let cluster =
            ServingCluster::new(&shared, &spec, 8, RoutePolicy::LeastLoaded)
                .unwrap();
        let report = cluster.drain().unwrap();
        assert_eq!(report.stats.completed, 0);
        assert_eq!(report.stats.total.n, 0);
        assert_eq!(report.stats.total.max_ms, 0.0);
        assert_eq!(report.stats.tokens_per_sec, 0.0);
        assert!(report.stats.tokens_per_sec.is_finite());
        for s in &report.stats.shards {
            assert!(s.tokens_per_sec.is_finite());
            assert_eq!(s.tokens_per_sec, 0.0);
        }
        assert_eq!(report.tokens_per_sec(), 0.0);
    }

    #[test]
    fn session_cache_defaults_on_and_counters_surface() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 8, RoutePolicy::LeastLoaded)
                .unwrap();
        assert!(cluster.sessions().is_some());
        let live = cluster.live_stats();
        assert_eq!(live.sessions.expect("session counters in live stats"),
                   crate::session::SessionCounters::default());
        // a session save round-trips through the threaded fleet
        cluster.try_submit_with(
            Request { id: 1, prompt: vec![4, 5, 6], gen_len: 2,
                      temperature: 0.0 },
            &SubmitOpts { save_session: Some(11), ..Default::default() })
            .unwrap();
        let report = cluster.drain().unwrap();
        assert_eq!(report.stats.completed, 1);
        assert_eq!(report.stats.sessions.unwrap().sessions, 1,
                   "suspended session resident after drain");
        // sessions disabled: session opts refused as Invalid, plain
        // requests unaffected, no counters in stats
        let mut off = ServingCluster::new_with_sessions(
            &shared, &spec, 8, RoutePolicy::LeastLoaded, None).unwrap();
        assert!(off.sessions().is_none());
        let refused = off.try_submit_with(
            Request { id: 2, prompt: vec![1, 2], gen_len: 1,
                      temperature: 0.0 },
            &SubmitOpts { save_session: Some(1), ..Default::default() })
            .unwrap_err();
        assert!(matches!(refused, SubmitRefused::Invalid(_)));
        off.try_submit(greedy(3)).unwrap();
        let report = off.drain().unwrap();
        assert_eq!(report.stats.completed, 1);
        assert!(report.stats.sessions.is_none());
    }

    #[test]
    fn rejects_bad_shard_counts() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7)
            .with_shards(0);
        assert!(ServingCluster::new(&shared, &spec, 8,
                                    RoutePolicy::LeastLoaded).is_err());
        let spec = spec.with_shards(BackendSpec::MAX_SHARDS + 1);
        assert!(ServingCluster::new(&shared, &spec, 8,
                                    RoutePolicy::LeastLoaded).is_err());
        // kind mismatch between spec and shared model is a config error
        let spec = BackendSpec::with(BackendKind::PackedPlanes, 2, 7);
        assert!(ServingCluster::new(&shared, &spec, 8,
                                    RoutePolicy::LeastLoaded).is_err());
    }

    /// id-sorted (id, tokens, logprob bits) rows — the comparison basis
    /// for crash-replay bit-identity.
    fn rows(report: &ClusterReport) -> Vec<(u64, Vec<i32>, u64)> {
        let mut v: Vec<(u64, Vec<i32>, u64)> = report.responses.iter()
            .map(|r| {
                let resp = r.done().expect("outcome must be Done");
                (resp.id, resp.generated.clone(),
                 resp.prompt_logprob.to_bits())
            })
            .collect();
        v.sort();
        v
    }

    #[test]
    fn supervised_shard_panic_replays_bit_identical() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let run = |faults: Option<Arc<FaultPlan>>| {
            let mut cluster = ServingCluster::new_with_options(
                &shared, &spec,
                ClusterOptions { queue_cap: 32, faults,
                                 ..Default::default() },
                Some(SessionCache::new(DEFAULT_SESSION_BYTES,
                                       DEFAULT_SESSION_GRID))).unwrap();
            for id in 0..12u64 {
                cluster.submit(greedy(id)).unwrap();
            }
            cluster.drain().unwrap()
        };
        let clean = run(None);
        assert_eq!(clean.stats.respawns, 0);
        let plan = Arc::new(FaultPlan::parse("panic:shard=0,step=4").unwrap());
        let chaos = run(Some(plan));
        assert_eq!(chaos.stats.respawns, 1, "supervisor respawned once");
        assert_eq!(chaos.responses.len(), 12,
                   "zero accepted-request loss across the crash");
        assert_eq!(rows(&clean), rows(&chaos),
                   "crash replay must be bit-identical");
    }

    #[test]
    fn unsupervised_shard_panic_fails_drain_typed() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let plan = Arc::new(FaultPlan::parse("panic:shard=0,step=2").unwrap());
        let mut cluster = ServingCluster::new_with_options(
            &shared, &spec,
            ClusterOptions { queue_cap: 32, supervise: false,
                             faults: Some(plan), ..Default::default() },
            None).unwrap();
        for id in 0..6u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        let err = cluster.drain().expect_err("dead shard must fail drain");
        assert!(err.to_string().contains("panicked"),
                "typed panic report, got: {err:#}");
    }

    #[test]
    fn expired_deadline_is_typed_not_silent() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let mut cluster = ServingCluster::new_with_options(
            &shared, &spec,
            ClusterOptions { queue_cap: 32,
                             deadline: Some(Duration::ZERO),
                             ..Default::default() },
            None).unwrap();
        for id in 0..5u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        // a per-request deadline overrides the cluster default
        cluster.try_submit_with(
            greedy(100),
            &SubmitOpts { deadline: Some(Duration::from_secs(3600)),
                          ..Default::default() }).unwrap();
        let report = cluster.drain().unwrap();
        assert_eq!(report.responses.len(), 6,
                   "every accepted request gets SOME typed outcome");
        let expired: Vec<u64> = report.responses.iter()
            .filter(|r| r.done().is_none())
            .map(|r| r.id())
            .collect();
        assert_eq!(expired.len(), 5, "zero budget expires at the shard");
        assert!(!expired.contains(&100),
                "the long per-request deadline must be served");
        assert_eq!(report.stats.expired, 5);
        assert_eq!(report.stats.completed, 1);
    }

    #[test]
    fn full_refusals_retry_with_backoff_until_accepted() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 1, 7);
        // pipeline capacity ~4 (front 1 + inbox 2 + slot); 12 immediate
        // submits of multi-step work would hit Full without retries
        let mut cluster = ServingCluster::new_with_options(
            &shared, &spec,
            ClusterOptions {
                queue_cap: 1,
                retry: RetrySpec { attempts: 500,
                                   backoff: Duration::from_millis(1) },
                ..Default::default()
            },
            None).unwrap();
        for id in 0..12u64 {
            cluster.try_submit(Request { id, prompt: vec![1, 2],
                                         gen_len: 16, temperature: 0.0 })
                .expect("bounded retry must absorb transient Full");
        }
        // draining is refused immediately, never retried
        cluster.close_intake();
        let t0 = Instant::now();
        let refused = cluster.try_submit(greedy(999)).unwrap_err();
        assert_eq!(refused, SubmitRefused::Draining);
        assert!(t0.elapsed() < Duration::from_millis(400),
                "Draining must not burn retry backoff");
        let report = cluster.drain().unwrap();
        assert_eq!(report.stats.completed, 12);
    }

    #[test]
    fn fingerprint_surfaces_on_the_cluster() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let cluster = ServingCluster::new(&shared, &spec, 8,
                                          RoutePolicy::LeastLoaded).unwrap();
        assert_eq!(cluster.fingerprint(), shared.fingerprint());
        assert!(cluster.supervised(), "supervision defaults on");
        assert_eq!(cluster.retry(), RetrySpec::default());
        assert!(cluster.default_deadline().is_none());
        assert!(cluster.faults().is_none());
        assert!(cluster.obs().is_none(),
                "tracing must default off (every hook a None branch)");
        drop(cluster);
    }

    #[test]
    fn traced_cluster_spans_and_retry_attempts_surface_in_stats() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let obs = Obs::new(&crate::obs::ObsSpec::default());
        let mut cluster = ServingCluster::new_with_options(
            &shared, &spec,
            ClusterOptions { queue_cap: 8, obs: Some(obs.clone()),
                             ..ClusterOptions::default() },
            None).unwrap();
        assert!(cluster.obs().is_some());
        for id in 0..6u64 {
            cluster.submit(greedy(id)).unwrap();
        }
        let report = cluster.drain().unwrap();
        assert_eq!(report.stats.completed, 6);
        // stats carry the observability surfaces end to end
        assert_eq!(report.stats.retry_attempts, 0);
        assert_eq!(report.stats.total_hist.total(), 6,
                   "one total-latency observation per request");
        assert!(!report.stats.stages.is_empty(),
                "per-shard stage breakdown missing from stats");
        let spans = obs.completed_spans();
        assert_eq!(spans.len(), 6, "one completed span per request");
        for s in &spans {
            assert!(s.done_us.is_some() && s.scheduled_us.is_some());
        }
    }
}
