//! Sharded serving cluster: one shared packed weight set, N engine-shard
//! workers, one async router.
//!
//! This is the "beyond one box" rung of the ROADMAP: the paper's §6
//! argument makes the weight stream the scarce resource, and PR 2/3
//! exploited that *within* one engine (one weight stream per step for
//! all decode slots, sharded across a thread pool). A single engine
//! worker thread is still the ceiling, though — this module scales out
//! by running **N whole engines** ([`crate::coordinator::InferenceServer`]
//! shards, each with its own decode loop on its own thread, its own
//! slots and its own GEMM thread pool) behind one front door.
//!
//! ## Shared-plane ownership
//!
//! Naively, N engines would mean N copies of the weights — multiplying
//! back exactly the 12× memory the paper saves. Instead the cluster owns
//! ONE [`SharedModel`](crate::engine::SharedModel): the binary/ternary
//! deployment weights are sampled, packed and BN-folded once, and every
//! shard's cell is a clone that aliases the same `Arc`-backed plane
//! allocations (see [`crate::quant::pack`]). Growing the cluster adds
//! slot state and scratch — tens of KB — never plane bytes;
//! `rust/tests/cluster_integration.rs` pins this down with
//! `Arc::strong_count` and pointer-identity assertions, and the
//! `serve_cluster` bench reports constant resident weight bytes across
//! shard counts.
//!
//! ## Architecture
//!
//! * **Front door**: clients [`ServingCluster::submit`] into a bounded
//!   MPMC queue ([`BoundedQueue`]); a full queue fails fast
//!   (backpressure), a draining cluster rejects new work but completes
//!   everything accepted.
//! * **Router**: one async thread pops the front queue and dispatches to
//!   per-shard bounded inboxes under a pluggable [`RoutePolicy`] —
//!   `least-loaded` (default: argmin of in-flight requests) or
//!   `round-robin`. A full inbox blocks the router, propagating
//!   pressure back to the front door instead of buffering unboundedly.
//! * **Shard workers**: each owns an `InferenceServer` over a
//!   [`from_shared`] backend and runs the continuous-batching loop —
//!   admit from inbox, step all active slots, emit completions. The
//!   single-server code path IS the 1-shard special case; the cluster
//!   adds routing around it, never a second decode loop.
//! * **Completions**: per-shard channels merge into one response stream
//!   (`mpsc` sender clones); [`ServingCluster::drain`] closes the front
//!   door, lets every accepted request finish, joins all threads and
//!   returns the merged responses plus [`ClusterStats`] (per-shard and
//!   whole-cluster tokens/sec, p50/p95/p99 latency).
//!
//! ## Why shard outputs are bit-identical to a single server
//!
//! A request's trajectory depends only on (a) the packed weights and
//! (b) its own token stream: its slot state is zeroed on admission, the
//! batched/threaded kernels are bit-identical to the per-slot reference
//! for every batch composition and thread count (PR 2/3 invariants), and
//! greedy sampling plus the prompt log-prob are pure functions of the
//! logits. Routing therefore only decides *where* and *when* a request
//! runs, never *what* it computes: for a greedy request set, a cluster
//! with any shard count and either policy produces bit-identical
//! generated tokens and prompt log-probs to one `InferenceServer` —
//! enforced by `cluster_integration.rs` and the `ci.sh` shards=1 vs
//! shards=2 digest diff. (At temperature > 0, sampled tokens depend on
//! each server's rng stream and therefore on scheduling; equivalence is
//! a greedy-decoding guarantee.)

mod queue;
mod stats;

pub use queue::{BoundedQueue, PushRefused};
pub use stats::{ClusterStats, ShardStats};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{latency_breakdown, validate_request,
                         InferenceServer, LoadSpec, Request, Response,
                         ServerStats};
use crate::engine::{from_shared, BackendSpec, SharedModel, ThreadPool};

/// How the router assigns requests to engine shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Dispatch to the shard with the fewest in-flight requests
    /// (routed minus completed); ties go to the lowest shard id.
    LeastLoaded,
    /// Dispatch strictly in rotation, ignoring load.
    RoundRobin,
}

impl RoutePolicy {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "least-loaded" | "least_loaded" | "ll" => RoutePolicy::LeastLoaded,
            "round-robin" | "round_robin" | "rr" => RoutePolicy::RoundRobin,
            other => anyhow::bail!(
                "unknown routing policy '{other}' (accepted: least-loaded | \
                 least_loaded | ll, round-robin | round_robin | rr)"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::RoundRobin => "round-robin",
        }
    }

    pub fn all() -> [RoutePolicy; 2] {
        [RoutePolicy::LeastLoaded, RoutePolicy::RoundRobin]
    }
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy::LeastLoaded
    }
}

impl std::fmt::Display for RoutePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// A completed request, tagged with the shard that served it.
#[derive(Clone, Debug)]
pub struct ClusterResponse {
    pub shard: usize,
    pub response: Response,
}

/// Everything a drained cluster run produced.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Merged response stream (arrival order; sort by id to compare).
    pub responses: Vec<ClusterResponse>,
    pub stats: ClusterStats,
}

impl ClusterReport {
    pub fn tokens_per_sec(&self) -> f64 {
        self.stats.tokens_per_sec
    }
}

type Routed = (Request, Instant);

/// The sharded serving cluster; see the module docs.
pub struct ServingCluster {
    front: Arc<BoundedQueue<Routed>>,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<ServerStats>>,
    routed: Arc<Vec<AtomicU64>>,
    done_rx: mpsc::Receiver<ClusterResponse>,
    vocab: usize,
    n_shards: usize,
    slots_per_shard: usize,
    weight_bytes: usize,
    policy: RoutePolicy,
    submitted: u64,
    started: Instant,
}

impl ServingCluster {
    /// Build `spec.shards` engine shards over `shared` (each
    /// [`from_shared`] — zero-copy on the plane bytes) and start the
    /// router + worker threads. `queue_cap` bounds the front door.
    ///
    /// With `spec.threads = 0` (auto), the machine's per-core GEMM
    /// worker budget is divided across the shards (`available / shards`
    /// workers each, min 1) so scaling out shards doesn't oversubscribe
    /// the CPU; an explicit thread count applies to every shard
    /// unchanged.
    pub fn new(shared: &SharedModel, spec: &BackendSpec, queue_cap: usize,
               policy: RoutePolicy) -> Result<Self> {
        let shards = spec.shards;
        anyhow::ensure!(shards >= 1, "need at least one engine shard");
        anyhow::ensure!(shards <= BackendSpec::MAX_SHARDS,
                        "shards {} out of range [1, {}]", shards,
                        BackendSpec::MAX_SHARDS);
        // auto thread budget (threads = 0) is divided across shards:
        // every shard owning a full one-pool-worker-per-core would
        // oversubscribe the machine shards-fold and the sweep would
        // measure contention, not scaling. Explicit counts pass
        // through untouched — oversubscription then is the
        // operator's stated choice.
        let mut shard_spec = *spec;
        if spec.batch_gemm && spec.threads == 0 {
            shard_spec.threads = (ThreadPool::available() / shards).max(1);
        }
        // build every shard engine up front so a bad spec fails before
        // any thread exists
        let mut servers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let backend = from_shared(shared, &shard_spec)?;
            servers.push(InferenceServer::with_backend(backend,
                                                       spec.slots.max(1)));
        }
        let front: Arc<BoundedQueue<Routed>> =
            Arc::new(BoundedQueue::new(queue_cap));
        let loads: Arc<Vec<AtomicU64>> =
            Arc::new((0..shards).map(|_| AtomicU64::new(0)).collect());
        let routed: Arc<Vec<AtomicU64>> =
            Arc::new((0..shards).map(|_| AtomicU64::new(0)).collect());
        let (done_tx, done_rx) = mpsc::channel();
        let mut inboxes: Vec<Arc<BoundedQueue<Routed>>> =
            Vec::with_capacity(shards);
        let mut workers = Vec::with_capacity(shards);
        for (shard, server) in servers.into_iter().enumerate() {
            // small bounded inbox: enough lookahead to refill slots
            // without stalling, small enough that backpressure reaches
            // the router (and through it, the front door) quickly
            let inbox = Arc::new(BoundedQueue::new((2 * spec.slots).max(2)));
            inboxes.push(inbox.clone());
            let loads_w = loads.clone();
            let done = done_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("rbtw-cluster-shard-{shard}"))
                .spawn(move || shard_worker(shard, server, inbox, loads_w,
                                            done));
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    for ib in &inboxes {
                        ib.close();
                    }
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(e).context("spawning a cluster shard worker");
                }
            }
        }
        // the workers hold the only senders: the merged stream closes
        // exactly when the last worker exits
        drop(done_tx);
        let router = {
            let front_r = front.clone();
            let loads_r = loads.clone();
            let routed_r = routed.clone();
            let inboxes_r = inboxes.clone();
            let spawned = std::thread::Builder::new()
                .name("rbtw-cluster-router".to_string())
                .spawn(move || router_loop(front_r, inboxes_r, loads_r,
                                           routed_r, policy));
            match spawned {
                Ok(h) => h,
                Err(e) => {
                    front.close();
                    for ib in &inboxes {
                        ib.close();
                    }
                    for h in workers {
                        let _ = h.join();
                    }
                    return Err(e).context("spawning the cluster router");
                }
            }
        };
        Ok(Self {
            front,
            router: Some(router),
            workers,
            routed,
            done_rx,
            vocab: shared.vocab(),
            n_shards: shards,
            slots_per_shard: spec.slots.max(1),
            weight_bytes: shared.weight_bytes(),
            policy,
            submitted: 0,
            started: Instant::now(),
        })
    }

    pub fn shards(&self) -> usize {
        self.n_shards
    }

    pub fn slots_per_shard(&self) -> usize {
        self.slots_per_shard
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Resident serving bytes — the ONE shared copy of packed planes +
    /// dense head. Constant in the shard count by construction.
    pub fn weight_bytes(&self) -> usize {
        self.weight_bytes
    }

    /// Front-door queue capacity (the fail-fast backpressure boundary).
    pub fn queue_capacity(&self) -> usize {
        self.front.capacity()
    }

    /// Requests waiting at the front door (not yet routed to a shard).
    pub fn pending(&self) -> usize {
        self.front.len()
    }

    /// Requests accepted so far.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }

    /// Enqueue a request at the front door. Fails fast — without
    /// touching any shard — when the bounded queue is full
    /// (backpressure) or the cluster is draining. Validation runs here,
    /// through the same [`validate_request`] the shard servers apply,
    /// so a cluster-accepted request can never be one a shard rejects.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        validate_request(&req, self.vocab)?;
        match self.front.try_push((req, Instant::now())) {
            Ok(()) => {
                self.submitted += 1;
                Ok(())
            }
            Err((_, PushRefused::Full)) => anyhow::bail!(
                "cluster queue full ({} pending)", self.front.len()),
            Err((_, PushRefused::Closed)) => anyhow::bail!(
                "cluster is draining; no new requests accepted"),
        }
    }

    /// Non-blocking read of the merged response stream. Responses taken
    /// here (streaming mode) are not repeated in [`Self::drain`]'s
    /// report.
    pub fn try_recv(&self) -> Option<ClusterResponse> {
        self.done_rx.try_recv().ok()
    }

    /// Graceful shutdown: stop intake, let every accepted request finish
    /// (router drains the front queue, shards drain their inboxes and
    /// slots), join all threads, and return the merged responses plus
    /// aggregated [`ClusterStats`].
    ///
    /// The latency percentiles summarize the responses returned by THIS
    /// call; responses already consumed via [`Self::try_recv`] are
    /// excluded from them (the per-shard counters and throughput totals
    /// still cover every request). Streaming consumers who need full
    /// latency percentiles should summarize their own stream.
    pub fn drain(mut self) -> Result<ClusterReport> {
        self.front.close();
        // the recv loop ends when the last worker exits and drops its
        // sender — i.e. exactly when all accepted work has completed
        let mut responses = vec![];
        while let Ok(r) = self.done_rx.recv() {
            responses.push(r);
        }
        if let Some(h) = self.router.take() {
            h.join()
                .map_err(|_| anyhow::anyhow!("cluster router panicked"))?;
        }
        let mut shard_servers = vec![];
        let mut panicked = vec![];
        for (i, h) in self.workers.drain(..).enumerate() {
            match h.join() {
                Ok(s) => shard_servers.push(s),
                Err(_) => panicked.push(i),
            }
        }
        anyhow::ensure!(panicked.is_empty(),
                        "cluster shard worker(s) {panicked:?} panicked");
        let wall_s = self.started.elapsed().as_secs_f64();
        let (queue, run, total) =
            latency_breakdown(responses.iter().map(|r| &r.response));
        let mut stats = ClusterStats { wall_s, queue, run, total,
                                       ..ClusterStats::default() };
        for (i, server) in shard_servers.into_iter().enumerate() {
            stats.completed += server.completed;
            stats.tokens_processed += server.tokens_processed;
            stats.engine_steps += server.engine_steps;
            stats.shards.push(ShardStats {
                shard: i,
                routed: self.routed[i].load(Ordering::SeqCst),
                tokens_per_sec: server.tokens_processed as f64
                    / wall_s.max(1e-12),
                server,
            });
        }
        stats.tokens_per_sec =
            stats.tokens_processed as f64 / wall_s.max(1e-12);
        Ok(ClusterReport { responses, stats })
    }
}

impl Drop for ServingCluster {
    /// Dropping without [`Self::drain`] still shuts down gracefully:
    /// close the front door and wait for the fleet (accepted work
    /// completes; its responses are discarded with the channel).
    fn drop(&mut self) {
        self.front.close();
        if let Some(h) = self.router.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn router_loop(front: Arc<BoundedQueue<Routed>>,
               inboxes: Vec<Arc<BoundedQueue<Routed>>>,
               loads: Arc<Vec<AtomicU64>>, routed: Arc<Vec<AtomicU64>>,
               policy: RoutePolicy) {
    let mut rr = 0usize;
    while let Some(item) = front.pop_wait() {
        let shard = match policy {
            RoutePolicy::RoundRobin => {
                let s = rr % inboxes.len();
                rr += 1;
                s
            }
            RoutePolicy::LeastLoaded => {
                let mut best = 0usize;
                let mut best_load = u64::MAX;
                for (i, l) in loads.iter().enumerate() {
                    let v = l.load(Ordering::SeqCst);
                    if v < best_load {
                        best = i;
                        best_load = v;
                    }
                }
                best
            }
        };
        loads[shard].fetch_add(1, Ordering::SeqCst);
        routed[shard].fetch_add(1, Ordering::SeqCst);
        // a full inbox blocks here — pressure propagates to the front
        // door, which is where submit() fails fast
        if inboxes[shard].push_wait(item).is_err() {
            // inbox closed under us: either teardown, or the shard
            // worker died (its exit guard closes its inbox so this
            // router can never block on a dead shard). The request is
            // shed; a dead worker additionally surfaces as an error
            // from drain()'s join.
            loads[shard].fetch_sub(1, Ordering::SeqCst);
            routed[shard].fetch_sub(1, Ordering::SeqCst);
        }
    }
    // front closed and fully routed: signal every shard to finish + exit
    for inbox in &inboxes {
        inbox.close();
    }
}

/// Closes a shard's inbox when its worker exits — HOWEVER it exits. A
/// panicking worker must not leave an open inbox behind: the router
/// would eventually block forever in `push_wait` on it, never close the
/// other shards' inboxes, and wedge the whole cluster (drain() and Drop
/// included). With the guard, the router's push simply fails, the other
/// shards drain normally, and the panic surfaces from drain()'s join.
struct InboxCloser(Arc<BoundedQueue<Routed>>);

impl Drop for InboxCloser {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// One engine shard: the continuous-batching loop over this shard's
/// private `InferenceServer`, fed from its bounded inbox. Exits when the
/// inbox is closed AND every admitted request has completed.
fn shard_worker(shard: usize, mut server: InferenceServer,
                inbox: Arc<BoundedQueue<Routed>>,
                loads: Arc<Vec<AtomicU64>>,
                done: mpsc::Sender<ClusterResponse>) -> ServerStats {
    let _closer = InboxCloser(inbox.clone());
    loop {
        // top up the admission queue without blocking while there is
        // runnable work
        while server.pending() < server.queue_capacity() {
            match inbox.try_pop() {
                Some((req, t0)) => server
                    .submit_at(req, t0)
                    .expect("cluster-validated request rejected by shard"),
                None => break,
            }
        }
        if server.pending() == 0 && server.active() == 0 {
            // idle: block for work, or exit once the inbox is closed
            // and drained
            match inbox.pop_wait() {
                Some((req, t0)) => {
                    server
                        .submit_at(req, t0)
                        .expect("cluster-validated request rejected by shard");
                    continue;
                }
                None => break,
            }
        }
        server.step().expect("engine step failed on a validated batch");
        while let Ok(resp) = server.done_rx.try_recv() {
            loads[shard].fetch_sub(1, Ordering::SeqCst);
            // a gone collector is not an error mid-teardown; keep
            // stepping so accepted work still runs to completion
            let _ = done.send(ClusterResponse { shard, response: resp });
        }
    }
    server.stats.clone()
}

/// Drive `load` through a fresh cluster over `shared` — the cluster twin
/// of [`crate::coordinator::run_load`]. Uses [`LoadSpec::requests`], so
/// the request set is byte-identical to the single-server harness for
/// the same spec (the basis of the shards=N equivalence checks).
/// `queue_cap` sizes the front door; the whole load is submitted up
/// front, so pass at least `load.n_requests` (it is clamped up to that)
/// unless the point is to exercise rejection.
pub fn run_cluster_load(shared: &SharedModel, spec: &BackendSpec,
                        policy: RoutePolicy, queue_cap: usize,
                        load: &LoadSpec) -> Result<ClusterReport> {
    let mut cluster = ServingCluster::new(
        shared, spec, queue_cap.max(load.n_requests).max(1), policy)?;
    for req in load.requests(cluster.vocab()) {
        cluster.submit(req)?;
    }
    cluster.drain()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{BackendKind, ModelWeights};

    fn shared_model() -> SharedModel {
        let w = ModelWeights::synthetic(20, 12, "ter", 0xC1);
        SharedModel::prepare(&w, BackendKind::PackedCpu, 7).unwrap()
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in RoutePolicy::all() {
            assert_eq!(RoutePolicy::parse(p.label()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("ll").unwrap(),
                   RoutePolicy::LeastLoaded);
        assert_eq!(RoutePolicy::default(), RoutePolicy::LeastLoaded);
        assert!(RoutePolicy::parse("random").is_err());
    }

    #[test]
    fn policy_parse_error_lists_every_accepted_spelling() {
        let err = format!("{:#}", RoutePolicy::parse("random").unwrap_err());
        for spelling in ["least-loaded", "least_loaded", "ll",
                         "round-robin", "round_robin", "rr"] {
            assert!(err.contains(spelling),
                    "parse error must list '{spelling}': {err}");
        }
    }

    #[test]
    fn serves_and_drains_all_requests() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 3, 7)
            .with_shards(2);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 32, RoutePolicy::LeastLoaded)
                .unwrap();
        assert_eq!(cluster.shards(), 2);
        assert_eq!(cluster.weight_bytes(), shared.weight_bytes());
        for id in 0..10u64 {
            cluster.submit(Request {
                id,
                prompt: vec![(id % 20) as i32, 3],
                gen_len: 3,
                temperature: 0.0,
            }).unwrap();
        }
        let report = cluster.drain().unwrap();
        assert_eq!(report.responses.len(), 10);
        let mut ids: Vec<u64> =
            report.responses.iter().map(|r| r.response.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10, "every request completed exactly once");
        assert_eq!(report.stats.completed, 10);
        let routed_total: u64 =
            report.stats.shards.iter().map(|s| s.routed).sum();
        assert_eq!(routed_total, 10, "router accounted every request");
        assert_eq!(report.stats.shards.len(), 2);
        assert_eq!(report.stats.total.n, 10);
        assert!(report.stats.tokens_per_sec > 0.0);
        for r in &report.responses {
            assert!(r.shard < 2);
            assert_eq!(r.response.generated.len(), 3);
            assert!(r.response.prompt_logprob <= 0.0);
        }
    }

    #[test]
    fn submit_validates_before_routing() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7);
        let mut cluster =
            ServingCluster::new(&shared, &spec, 8, RoutePolicy::RoundRobin)
                .unwrap();
        assert!(cluster.submit(Request { id: 1, prompt: vec![],
                                         gen_len: 1, temperature: 0.0 })
            .is_err());
        assert!(cluster.submit(Request { id: 2, prompt: vec![99],
                                         gen_len: 1, temperature: 0.0 })
            .is_err());
        assert_eq!(cluster.submitted(), 0);
        let report = cluster.drain().unwrap();
        assert!(report.responses.is_empty());
        assert_eq!(report.stats.completed, 0);
    }

    #[test]
    fn rejects_bad_shard_counts() {
        let shared = shared_model();
        let spec = BackendSpec::with(BackendKind::PackedCpu, 2, 7)
            .with_shards(0);
        assert!(ServingCluster::new(&shared, &spec, 8,
                                    RoutePolicy::LeastLoaded).is_err());
        let spec = spec.with_shards(BackendSpec::MAX_SHARDS + 1);
        assert!(ServingCluster::new(&shared, &spec, 8,
                                    RoutePolicy::LeastLoaded).is_err());
        // kind mismatch between spec and shared model is a config error
        let spec = BackendSpec::with(BackendKind::PackedPlanes, 2, 7);
        assert!(ServingCluster::new(&shared, &spec, 8,
                                    RoutePolicy::LeastLoaded).is_err());
    }
}
