//! Bounded MPMC queue — the cluster's front door and per-shard inboxes.
//!
//! Plain `Mutex<VecDeque>` + two `Condvar`s (no crates, matching the
//! repo's offline constraint): any number of producers and consumers,
//! fail-fast [`BoundedQueue::try_push`] for the backpressure boundary,
//! blocking [`BoundedQueue::push_wait`] for the router (so a full shard
//! inbox propagates pressure back to the front door instead of buffering
//! unboundedly), blocking [`BoundedQueue::pop_wait`] for idle workers,
//! and [`BoundedQueue::close`] for graceful drain: a closed queue
//! rejects new items but still hands out everything already queued, so
//! shutdown never drops accepted work.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Why a non-blocking push was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushRefused {
    /// At capacity — the backpressure signal; retry later or shed load.
    Full,
    /// Draining/shut down — no new work is accepted.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer queue; see the module docs.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to >= 1).
    pub fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Fail-fast enqueue: refuses (returning the item) when full or
    /// closed, never blocks. The backpressure boundary.
    pub fn try_push(&self, item: T) -> Result<(), (T, PushRefused)> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err((item, PushRefused::Closed));
        }
        if s.items.len() >= self.cap {
            return Err((item, PushRefused::Full));
        }
        s.items.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking enqueue: waits for space; returns the item back only if
    /// the queue closes while waiting (or was already closed).
    pub fn push_wait(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.closed {
                return Err(item);
            }
            if s.items.len() < self.cap {
                s.items.push_back(item);
                drop(s);
                self.not_empty.notify_one();
                return Ok(());
            }
            s = self.not_full.wait(s).unwrap();
        }
    }

    /// Non-blocking dequeue.
    pub fn try_pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        let item = s.items.pop_front();
        drop(s);
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Blocking dequeue: waits for an item; `None` only once the queue
    /// is closed AND fully drained (the worker-exit signal).
    pub fn pop_wait(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Close the queue: new pushes fail, queued items still drain,
    /// every blocked waiter wakes. Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bounded_fifo_with_fail_fast_overflow() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.capacity(), 2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err((3, PushRefused::Full)));
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        q.try_push(3).unwrap();
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = BoundedQueue::new(4);
        q.try_push("a").unwrap();
        q.close();
        assert_eq!(q.try_push("b"), Err(("b", PushRefused::Closed)));
        assert!(q.push_wait("c").is_err());
        // queued work still comes out; then the exit signal
        assert_eq!(q.pop_wait(), Some("a"));
        assert_eq!(q.pop_wait(), None);
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn cross_thread_producers_and_consumers() {
        let q = Arc::new(BoundedQueue::new(3));
        let n = 200u64;
        let producers: Vec<_> = (0..2)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..n {
                        q.push_wait(p * n + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut got = vec![];
                    while let Some(v) = q.pop_wait() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<u64> = (0..2 * n).collect();
        assert_eq!(all, want, "every item delivered exactly once");
    }

    #[test]
    fn concurrent_close_never_loses_accepted_or_accepts_after_close() {
        // Race close() against a herd of try_push-ers, many rounds. Two
        // invariants: (1) every ACCEPTED item is still drainable after
        // close — close rejects new work, it never drops queued work;
        // (2) once a pusher has OBSERVED Closed, every later try_push
        // from that thread is also Closed — the closed state is sticky
        // and monotonic, with no accept-after-close window.
        for round in 0..40u64 {
            let q = Arc::new(BoundedQueue::new(usize::MAX >> 1));
            let pushers: Vec<_> = (0..4u64)
                .map(|p| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        let mut accepted = vec![];
                        let mut saw_closed = false;
                        for i in 0..500u64 {
                            let v = p * 1_000_000 + i;
                            match q.try_push(v) {
                                Ok(()) => {
                                    assert!(!saw_closed,
                                            "accept after Closed observed");
                                    accepted.push(v);
                                }
                                Err((w, PushRefused::Closed)) => {
                                    assert_eq!(w, v, "refusal returns item");
                                    saw_closed = true;
                                }
                                Err((_, PushRefused::Full)) => {
                                    unreachable!("capacity is effectively \
                                                  unbounded here")
                                }
                            }
                        }
                        accepted
                    })
                })
                .collect();
            // close at a varying point in the race
            if round % 4 != 0 {
                std::thread::yield_now();
            }
            q.close();
            let mut accepted: Vec<u64> = pushers
                .into_iter()
                .flat_map(|p| p.join().unwrap())
                .collect();
            let mut drained = vec![];
            while let Some(v) = q.pop_wait() {
                drained.push(v);
            }
            accepted.sort_unstable();
            drained.sort_unstable();
            assert_eq!(accepted, drained,
                       "round {round}: accepted set == drained set");
        }
    }
}
