//! rbtw — Learning Recurrent Binary/Ternary Weights (ICLR 2019).
//!
//! Three-layer reproduction: Pallas kernels (L1) and JAX models (L2) are
//! AOT-lowered at build time to HLO text artifacts; this crate (L3) owns
//! the runtime — training orchestration, serving, the bit-packed popcount
//! inference engine, and the hardware (ASIC) simulator of the paper's §6.
//!
//! # Serving: the engine layer
//!
//! Deployment inference goes through [`engine`]: the continuous-batching
//! [`coordinator::InferenceServer`] drives an [`engine::InferBackend`]
//! trait object, so the dense PJRT executable and the multiplier-free
//! packed engines are interchangeable:
//!
//! ```ignore
//! use rbtw::engine::{open, BackendKind, BackendSpec};
//! use rbtw::coordinator::InferenceServer;
//!
//! // serve from 2-bit packed ternary weights — no PJRT session built
//! let spec = BackendSpec { kind: BackendKind::PackedCpu, ..Default::default() };
//! let backend = open(std::path::Path::new("artifacts"), "char_ptb_ter", &spec)?;
//! let mut server = InferenceServer::with_backend(backend, 256);
//! ```
//!
//! Backends: [`engine::BackendKind::PjrtDense`] (dense f32 via the AOT
//! `infer_*` executables), [`engine::BackendKind::PackedCpu`] (LUT GEMV +
//! one-hot row gather over sign/mask planes) and
//! [`engine::BackendKind::PackedPlanes`] (precomputed pos/neg bit
//! planes). The packed backends serve a [`quant::PackedStack`] of
//! [`quant::RecurrentCell`] layers — LSTM or GRU
//! ([`quant::CellArch`]), any depth; the paper's stacked-LM (Tables
//! 2–3) and GRU (Table 6) configurations run on the same packed
//! substrate as the single-layer LSTM. Slot state lives in flat f32
//! buffers and resident weights at 1–2 bits each — the paper's 12×
//! memory claim, measurable via
//! [`engine::InferBackend::weight_bytes`] — and by default every
//! active decode slot steps through one batched GEMM per gate matrix
//! (a single weight stream per engine step; see [`quant::gemm`] and
//! [`engine::BackendSpec::batch_gemm`]). The batched path is
//! SIMD-tiled (8-lane [`quant::F32x8`] batch blocks) and sharded by
//! output column across a persistent worker pool
//! ([`engine::ThreadPool`], sized by [`engine::BackendSpec::threads`]);
//! logits are bit-identical for every thread count, cell arch and
//! stack depth.
//!
//! # Cluster serving
//!
//! Beyond one engine, [`cluster::ServingCluster`] runs N engine shards —
//! each a full continuous-batching `InferenceServer` on its own thread —
//! over ONE shared packed weight set ([`engine::SharedModel`]; the plane
//! bytes are `Arc`-backed, so shards alias a single resident
//! allocation). A bounded MPMC front door plus an async router
//! (least-loaded or round-robin, [`cluster::RoutePolicy`]) feed the
//! shards; completions merge into one response stream. Greedy cluster
//! responses are bit-identical to the single server for every shard
//! count and policy. The fleet is live-mutable: [`cluster::ServingCluster::add_shard`]
//! grows it (a plane-`Arc` refcount bump, no weight copy) and
//! [`cluster::ServingCluster::remove_shard`] drains and retires one
//! shard while the rest keep serving; admission is typed
//! ([`cluster::SubmitRefused`]) so overload and drain are
//! distinguishable refusals rather than one opaque error.
//!
//! # Network front door
//!
//! [`frontdoor::FrontDoor`] puts a TCP listener in front of the
//! cluster — hand-rolled over `std::net` with a length-prefixed text
//! protocol ([`frontdoor::proto`]): an acceptor plus per-connection
//! reader/writer threads feed the bounded cluster queue, and a pump
//! thread streams each completion back as per-token `tok` frames the
//! moment the merged response stream yields it. The wire carries the
//! prompt log-prob as raw f64 bits, so socket responses are
//! bit-identical to an in-process run of the same model — the same
//! digest gate the cluster layer already passes, extended across the
//! network hop. Live fleet operations (`add-shard`, `remove-shard`,
//! `metrics`, `drain`) ride the same protocol; `rbtw serve --listen`
//! exposes the whole thing from the CLI with a stdin operator console.
//!
//! # Session cache
//!
//! [`session`] exploits the recurrent substrate's asymmetric advantage
//! over transformer serving: per-slot state is `O(layers × hidden)` and
//! constant in sequence length, so snapshots are cheap at any prompt
//! depth. [`engine::InferBackend::snapshot_slot`] /
//! [`engine::InferBackend::restore_slot`] export/import one slot's
//! state as an opaque [`session::SlotState`] (typed
//! [`session::StateError`] on any mismatch), and
//! [`session::SessionCache`] layers three moves on top: a keyed
//! **prefix cache** (requests sharing a system prompt skip its prefill,
//! bit-exactly), **suspend/resume** (a completed request's state
//! outlives its slot under a client-chosen session id and resumes on
//! any shard — state travels through the router inside
//! [`session::PreparedSubmit`]), and a bounded **LRU byte budget** with
//! hit/miss/evict gauges in `live_stats` and `/metrics`. The `session`
//! / `resume` wire verbs expose it through the front door.
//!
//! # Failure model
//!
//! The serving stack makes three hard guarantees, enforced by
//! `rust/tests/faults_integration.rs` and the ci.sh chaos gate (which
//! scripts failures deterministically via [`faults::FaultPlan`]):
//!
//! * **Shard death loses no accepted work.** Every shard serve loop
//!   runs panic-contained (`catch_unwind`); its in-thread supervisor
//!   rebuilds the engine from [`engine::SharedModel`] (a plane-`Arc`
//!   refcount bump, no weight copy) and re-admits the dead
//!   generation's in-flight requests — the same `PreparedSubmit`s that
//!   passed [`session::prepare_with`] at admission. Greedy decode is
//!   deterministic and a slot's trajectory depends only on the packed
//!   weights and its own token stream, so the replay produces
//!   bit-identical tokens and prompt-log-prob bits. Completions are
//!   delivered at-least-once across a crash (exactly-once to wire
//!   clients — the front door drops duplicate ids); suspended sessions
//!   live in the cluster-wide [`session::SessionCache`], not in any
//!   shard, and survive. Respawns surface in `live_stats` and
//!   `/metrics` (`rbtw_cluster_respawns`). With supervision off, a
//!   panicking shard fails the final drain with a typed error instead.
//! * **Deadline expiry is a typed refusal, not silent loss.** A
//!   per-request deadline (wire `deadline=<ms>` field or the cluster
//!   default) rides [`session::SubmitOpts`] through admission and is
//!   checked when a shard dequeues the request: expired work is never
//!   stepped, and the client gets a typed `expired` reply
//!   ([`cluster::ShardOutcome::Expired`]). `Full` refusals at
//!   admission can be retried with bounded exponential backoff
//!   ([`cluster::RetrySpec`]); `Draining` refusals are never retried.
//! * **A corrupt checkpoint is a typed load error, not wrong logits.**
//!   An FNV-1a fingerprint over every packed plane word and the f32
//!   head bits is taken at pack/export time and re-verified over the
//!   built stack at load ([`engine::SharedModel::prepare`]); any
//!   mismatch fails with [`engine::IntegrityError`] before a single
//!   request is served. The loaded fingerprint is exported via
//!   `/metrics` so a fleet can assert every shard serves the same
//!   bits.
//!
//! # Datapaths
//!
//! The packed engines store *weights* at 1–2 bits, but historically ran
//! every *activation* in f32. [`quant::act`] closes those last f32
//! islands behind an explicit per-backend knob,
//! [`engine::BackendSpec::datapath`] (`--datapath` on the CLI, `[serve]
//! datapath` in config):
//!
//! * `f32` (default) — **bit-identical to the pre-datapath engine**:
//!   none of the low-bit activation code executes, and every existing
//!   digest/equivalence gate keeps its exact output. This is the escape
//!   hatch — if a low-bit path ever misbehaves in production, `--datapath
//!   f32` restores the historical numerics with no rebuild.
//! * `lut8` — the gate tail's tanh/sigmoid evaluate through shared
//!   256-entry int8 lookup tables ([`quant::act::lut`], rounding rule
//!   documented there); GEMMs and the LM head stay f32.
//! * `xnor` — the full low-bit path: 64K-entry int16 gate LUTs, hidden
//!   states binarized per step ([`quant::act::BinarizedBatch`]) so the
//!   recurrent GEMM runs as pure xnor/popcount over the resident weight
//!   bit planes ([`quant::gemm::gemm_xnor`], surfaced as the
//!   `xnor_gemm` stage in `rbtw_engine_stage_seconds`), and an int8 LM
//!   head with fused top-k ([`quant::act::QuantHead`]).
//!
//! What stays exact under every datapath: token/one-hot gathers, packed
//! weight planes, slot state layout, snapshot/restore, and the
//! scheduler — a low-bit datapath changes *numerics inside a step*,
//! never *which* steps run. Low-bit digests are still deterministic and
//! invariant across thread/shard counts (ci.sh gates `xnor` across
//! threads {1,4} × shards {1,2}); they are simply not bit-equal to
//! `f32`. Task-level impact is measured by `rbtw accuracy` ([`accuracy`]),
//! which writes per-table deltas vs the f32 tail to
//! `BENCH_accuracy_datapath.json`; the ASIC model mirrors the same knob
//! via `hwsim::datapath_config` so `rbtw stage-compare` can line up
//! measured stage seconds against modeled ones.
//!
//! # Observability
//!
//! [`obs`] is the flight-recorder + tracing layer (`--trace` /
//! `[serve] trace`, default off). What is recorded when it is on:
//! **per-request spans** (admission → route → inbox dequeue → slot
//! schedule → first token → done, with retry/replay/expiry
//! annotations), a bounded lock-light **flight recorder** of
//! structured events (refusals, expiries, respawns, session
//! hits/evictions, slow-reader sheds), and **per-stage engine time**
//! (the packed backend attributes each pooled dispatch — inter-layer
//! x-GEMM, recurrent gate GEMM, folded-BN gate tail, LM head — to a
//! per-shard [`obs::StageAccum`], the software counterpart of
//! `hwsim::latency`'s datapath stages). `/metrics` renders through the
//! typed [`obs::Registry`] (Prometheus text with log-bucketed latency
//! histograms, [`obs::LogHistogram`]) whether tracing is on or not.
//!
//! Overhead discipline: every hook is an `Option<Arc<obs::Obs>>` that
//! does nothing on `None` — no timestamps, no allocation — the same
//! zero-cost-when-off contract as [`faults`]. Traced greedy digests
//! are bit-identical to untraced ones (`rust/tests/
//! obs_equivalence.rs` + a ci.sh gate).
//!
//! To open a trace: `rbtw serve ... --trace --trace-out trace.json`
//! (written at drain), the `trace` operator-console command, or the
//! `trace` wire verb ([`frontdoor::FrontDoorClient::trace`]); load the
//! JSON in `chrome://tracing` or <https://ui.perfetto.dev> (one pid
//! per shard, one tid per slot).

pub mod accuracy;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod faults;
pub mod frontdoor;
pub mod hwsim;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod session;
pub mod util;
