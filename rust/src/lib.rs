//! rbtw — Learning Recurrent Binary/Ternary Weights (ICLR 2019).
//!
//! Three-layer reproduction: Pallas kernels (L1) and JAX models (L2) are
//! AOT-lowered at build time to HLO text artifacts; this crate (L3) owns
//! the runtime — training orchestration, serving, the bit-packed popcount
//! inference engine, and the hardware (ASIC) simulator of the paper's §6.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod hwsim;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;
