//! Export trained weights into the deployment representation: stochastic
//! binary/ternary sampling of the shadow weights (Eq. 4–6, identical math
//! to `python/compile/quantizers.py`) followed by bit-plane packing for
//! the popcount engine — the "extracted weights" the paper ships to its
//! accelerator.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::quant::{PackedBinary, PackedTernary};
use crate::runtime::Session;
use crate::util::Rng;

/// One packed recurrent matrix.
pub enum PackedMatrix {
    Binary(PackedBinary),
    Ternary(PackedTernary),
    /// FP configs keep dense weights (baseline comparisons).
    Dense { rows: usize, cols: usize, data: Vec<f32> },
}

impl PackedMatrix {
    pub fn bytes(&self) -> usize {
        match self {
            PackedMatrix::Binary(b) => b.packed_bytes(),
            PackedMatrix::Ternary(t) => t.packed_bytes(),
            PackedMatrix::Dense { data, .. } => data.len() * 4,
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        match self {
            PackedMatrix::Binary(b) => (b.rows, b.cols),
            PackedMatrix::Ternary(t) => (t.rows, t.cols),
            PackedMatrix::Dense { rows, cols, .. } => (*rows, *cols),
        }
    }

    /// FNV-1a integrity fingerprint of this matrix's export bits (see
    /// [`PackedBinary::fingerprint`] / [`PackedTernary::fingerprint`];
    /// dense baselines hash dims + raw f32 bits under a `"fp "` tag).
    pub fn fingerprint(&self) -> u64 {
        match self {
            PackedMatrix::Binary(b) => b.fingerprint(),
            PackedMatrix::Ternary(t) => t.fingerprint(),
            PackedMatrix::Dense { rows, cols, data } => {
                use crate::quant::pack::{fnv_feed, FNV_OFFSET};
                let mut h = FNV_OFFSET;
                fnv_feed(&mut h, b"fp ");
                fnv_feed(&mut h, &(*rows as u64).to_le_bytes());
                fnv_feed(&mut h, &(*cols as u64).to_le_bytes());
                for v in data {
                    fnv_feed(&mut h, &v.to_bits().to_le_bytes());
                }
                h
            }
        }
    }
}

/// All recurrent matrices of a model, packed.
pub struct PackedModel {
    pub quantizer: String,
    pub matrices: BTreeMap<String, PackedMatrix>,
}

impl PackedModel {
    pub fn total_bytes(&self) -> usize {
        self.matrices.values().map(|m| m.bytes()).sum()
    }

    /// Whole-export integrity fingerprint: every matrix name and its
    /// [`PackedMatrix::fingerprint`] in `BTreeMap` (sorted-name) order —
    /// the same order `export_packed` samples in, so two exports of the
    /// same session + seed fingerprint identically.
    pub fn fingerprint(&self) -> u64 {
        use crate::quant::pack::{fnv_feed, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        for (name, m) in &self.matrices {
            fnv_feed(&mut h, name.as_bytes());
            fnv_feed(&mut h, &m.fingerprint().to_le_bytes());
        }
        h
    }
}

/// Glorot bound for a (fan_in, fan_out) matrix — the paper's fixed alpha.
/// Must match `quantizers.glorot_alpha` on the python side.
pub fn glorot_alpha(fan_in: usize, fan_out: usize) -> f32 {
    (6.0 / (fan_in + fan_out) as f64).sqrt() as f32
}

/// Stochastically quantize one shadow-weight matrix (Eq. 4–6).
///
/// Public so the serving engine can sample deployment weights directly
/// from host-side shadow values (artifact init segments or a checkpoint)
/// without a live `Session`.
pub fn sample_quantized(quantizer: &str, w: &[f32], rows: usize, cols: usize,
                        rng: &mut Rng) -> Result<PackedMatrix> {
    let alpha = glorot_alpha(rows, cols);
    match quantizer {
        "bin" => {
            let data: Vec<f32> = w
                .iter()
                .map(|&x| {
                    let wn = (x / alpha).clamp(-1.0, 1.0);
                    let p1 = (wn + 1.0) * 0.5;
                    if rng.bernoulli(p1 as f64) { alpha } else { -alpha }
                })
                .collect();
            Ok(PackedMatrix::Binary(PackedBinary::pack(&data, rows, cols, alpha)))
        }
        "ter" => {
            let data: Vec<f32> = w
                .iter()
                .map(|&x| {
                    let wn = (x / alpha).clamp(-1.0, 1.0);
                    if rng.bernoulli(wn.abs() as f64) {
                        alpha * wn.signum()
                    } else {
                        0.0
                    }
                })
                .collect();
            Ok(PackedMatrix::Ternary(PackedTernary::pack(&data, rows, cols, alpha)))
        }
        "fp" => Ok(PackedMatrix::Dense { rows, cols, data: w.to_vec() }),
        other => bail!("no packed export for quantizer '{other}'"),
    }
}

/// Export every recurrent matrix of a live session.
pub fn export_packed(sess: &Session, seed: u64) -> Result<PackedModel> {
    let quantizer = sess.meta.quantizer().to_string();
    let rec_names: Vec<String> = sess
        .meta
        .footprint
        .at("recurrent_names")
        .as_arr()
        .map(|a| a.iter().map(|x| x.as_str().unwrap().to_string()).collect())
        .unwrap_or_default();
    let mut rng = Rng::new(seed);
    let mut matrices = BTreeMap::new();
    for name in rec_names {
        let idx = sess
            .params
            .index_of(&name)
            .ok_or_else(|| anyhow::anyhow!("missing param {name}"))?;
        let shape = &sess.params.shapes[idx];
        anyhow::ensure!(shape.len() == 2, "{name} not a matrix");
        let data = sess.params.get_f32(&name)?;
        let m = sample_quantized(&quantizer, &data, shape[0], shape[1],
                                 &mut rng.fork(matrices.len() as u64))?;
        matrices.insert(name, m);
    }
    Ok(PackedModel { quantizer, matrices })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glorot_matches_python() {
        // python: math.sqrt(6/(96+384)) = 0.11180339887498948
        let a = glorot_alpha(96, 384);
        assert!((a - 0.111_803_4).abs() < 1e-6);
    }

    #[test]
    fn binary_sampling_probability() {
        // w = 0 should sample +alpha half the time.
        let mut rng = Rng::new(5);
        let w = vec![0.0f32; 10_000];
        let m = sample_quantized("bin", &w, 100, 100, &mut rng).unwrap();
        if let PackedMatrix::Binary(b) = m {
            let ones: usize = b.unpack().iter().filter(|&&x| x > 0.0).count();
            let rate = ones as f64 / 10_000.0;
            assert!((rate - 0.5).abs() < 0.03, "rate {rate}");
        } else {
            panic!("expected binary");
        }
    }

    #[test]
    fn ternary_zero_stays_zero() {
        let mut rng = Rng::new(6);
        let w = vec![0.0f32; 1000];
        let m = sample_quantized("ter", &w, 100, 10, &mut rng).unwrap();
        if let PackedMatrix::Ternary(t) = m {
            assert_eq!(t.density(), 0.0);
        } else {
            panic!("expected ternary");
        }
    }

    #[test]
    fn export_fingerprints_distinguish_models() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 64.0).collect();
        let mk = |q: &str, seed: u64| {
            let mut matrices = BTreeMap::new();
            matrices.insert(
                "l0/wx".to_string(),
                sample_quantized(q, &w, 8, 8, &mut Rng::new(seed)).unwrap());
            PackedModel { quantizer: q.to_string(), matrices }
        };
        for q in ["bin", "ter", "fp"] {
            assert_eq!(mk(q, 3).fingerprint(), mk(q, 3).fingerprint(),
                       "{q}: same sample, same fingerprint");
        }
        // different sampled bits and different quantizers both move it
        assert_ne!(mk("ter", 3).fingerprint(), mk("ter", 4).fingerprint());
        assert_ne!(mk("bin", 3).fingerprint(), mk("ter", 3).fingerprint());
        // the name participates: same bits under another key differ
        let mut a = mk("fp", 3);
        let m = a.matrices.remove("l0/wx").unwrap();
        a.matrices.insert("l1/wx".to_string(), m);
        assert_ne!(a.fingerprint(), mk("fp", 3).fingerprint());
    }

    #[test]
    fn saturated_weights_are_deterministic() {
        let mut rng = Rng::new(7);
        let alpha = glorot_alpha(10, 10);
        let w = vec![alpha; 100]; // wn = +1 -> P(+1) = 1
        let m = sample_quantized("bin", &w, 10, 10, &mut rng).unwrap();
        if let PackedMatrix::Binary(b) = m {
            assert!(b.unpack().iter().all(|&x| x > 0.0));
        } else {
            panic!();
        }
    }
}
