//! Checkpoint format: a self-describing binary container for the live
//! params/state/opt groups of a [`crate::runtime::Session`].
//!
//! Layout (little-endian):
//!   magic "RBTW" | version u32 | n_entries u32
//!   per entry: group_len u32 | group bytes | name_len u32 | name bytes |
//!              rank u32 | dims u64* | data_len u64 | f32 data
//! No serde offline — the codec is hand-rolled and round-trip tested.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"RBTW";
const VERSION: u32 = 1;

/// One named array with its group tag.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub group: String,
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// In-memory checkpoint.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub entries: Vec<Entry>,
}

impl Checkpoint {
    pub fn push(&mut self, group: &str, name: &str, shape: Vec<usize>,
                data: Vec<f32>) {
        self.entries.push(Entry {
            group: group.to_string(),
            name: name.to_string(),
            shape,
            data,
        });
    }

    /// Entries of one group keyed by name.
    pub fn group(&self, group: &str) -> BTreeMap<&str, &Entry> {
        self.entries
            .iter()
            .filter(|e| e.group == group)
            .map(|e| (e.name.as_str(), e))
            .collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.entries.len() as u32).to_le_bytes())?;
        for e in &self.entries {
            for s in [&e.group, &e.name] {
                f.write_all(&(s.len() as u32).to_le_bytes())?;
                f.write_all(s.as_bytes())?;
            }
            f.write_all(&(e.shape.len() as u32).to_le_bytes())?;
            for &d in &e.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(&(e.data.len() as u64).to_le_bytes())?;
            for &x in &e.data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a rbtw checkpoint");
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let n = read_u32(&mut f)? as usize;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let group = read_string(&mut f)?;
            let name = read_string(&mut f)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let len = read_u64(&mut f)? as usize;
            let expect: usize = shape.iter().product::<usize>().max(1);
            if len != expect {
                bail!("corrupt checkpoint: {name} len {len} vs shape {shape:?}");
            }
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            entries.push(Entry { group, name, shape, data });
        }
        Ok(Self { entries })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_string<R: Read>(r: &mut R) -> Result<String> {
    let len = read_u32(r)? as usize;
    if len > 1 << 20 {
        bail!("implausible string length {len}");
    }
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b).context("bad utf-8 in checkpoint")?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut c = Checkpoint::default();
        c.push("params", "l0/wx", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        c.push("params", "l0/b", vec![4], vec![0.0, -1.0, 1.5, 2.5]);
        c.push("state", "l0/rm_x", vec![4], vec![0.1; 4]);
        c
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("rbtw_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(c, loaded);
    }

    #[test]
    fn group_accessor() {
        let c = sample();
        let params = c.group("params");
        assert_eq!(params.len(), 2);
        assert!(params.contains_key("l0/wx"));
        assert_eq!(c.group("state").len(), 1);
        assert_eq!(c.group("nope").len(), 0);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("rbtw_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
