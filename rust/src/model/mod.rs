//! Model-state management: checkpoints and packed-weight export.

pub mod checkpoint;
pub mod export;

pub use checkpoint::{Checkpoint, Entry};
pub use export::{export_packed, sample_quantized, PackedModel, PackedMatrix};
