#!/usr/bin/env bash
# Tier-1 verification (offline): build, test, and (when rustfmt is
# installed) check formatting. Run from anywhere; works without network —
# all dependencies are vendored path crates (see Cargo.toml).
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

# The main test pass doubles as the first equivalence run: the
# seed_matrix test in engine_equivalence drives packed-cpu/packed-planes
# x per-slot/batched x {lstm, gru} x layers {1, 2}, asserts bit-for-bit
# logits per config, and writes a digest of the logit streams when
# RBTW_EQUIV_DIGEST is set (one line per arch x depth config).
# RBTW_THREADS=1 pins the batched configs to the fully inline path.
echo "== cargo test -q (equivalence run 1: threads=1) =="
mkdir -p target
rm -f target/equiv_digest_a.txt target/equiv_digest_b.txt
RBTW_EQUIV_DIGEST=target/equiv_digest_a.txt RBTW_THREADS=1 cargo test -q

# Second equivalence run re-drives the seed matrix (all four
# arch x depth configs) with the batched configs sharded across 4
# worker threads. One cmp then catches BOTH failure modes: run-to-run
# nondeterminism AND any thread-count leak into the logits — for
# shallow LSTMs, stacked LSTMs and GRUs alike — either is a serving
# bug even when each run is internally consistent.
echo "== cross-backend equivalence (run 2: threads=4, determinism + thread invariance) =="
RBTW_EQUIV_DIGEST=target/equiv_digest_b.txt RBTW_THREADS=4 \
    cargo test -q --test engine_equivalence
for f in target/equiv_digest_a.txt target/equiv_digest_b.txt; do
    if [ ! -s "$f" ]; then
        echo "FAIL: $f missing or empty (seed-matrix test did not write it)"
        exit 1
    fi
done
if ! cmp -s target/equiv_digest_a.txt target/equiv_digest_b.txt; then
    echo "FAIL: equivalence digests differ between threads=1 and threads=4 runs"
    echo "      (nondeterminism or thread-count-dependent logits):"
    diff target/equiv_digest_a.txt target/equiv_digest_b.txt || true
    exit 1
fi
echo "equivalence digests stable across runs and thread counts (1 vs 4):"
cat target/equiv_digest_a.txt

# Cluster determinism: the identical greedy request set served through a
# 1-shard and a 2-shard ServingCluster (over a 2-layer packed GRU, so
# the stacked/GRU path is the one being digested) must digest
# identically (the test also asserts each digest equals the
# single-InferenceServer reference in-process). A mismatch means shard
# count or routing leaked into the responses — a serving bug even when
# each run is self-consistent.
echo "== cluster determinism (shards=1 vs shards=2 response digests) =="
rm -f target/cluster_digest_1.txt target/cluster_digest_2.txt
# (filtered to the digest test — the rest of the suite already ran in
# the main cargo test pass above)
RBTW_CLUSTER_DIGEST=target/cluster_digest_1.txt RBTW_CLUSTER_SHARDS=1 \
    cargo test -q --test cluster_integration cluster_digest_is_shard_invariant
RBTW_CLUSTER_DIGEST=target/cluster_digest_2.txt RBTW_CLUSTER_SHARDS=2 \
    cargo test -q --test cluster_integration cluster_digest_is_shard_invariant
for f in target/cluster_digest_1.txt target/cluster_digest_2.txt; do
    if [ ! -s "$f" ]; then
        echo "FAIL: $f missing or empty (cluster digest test did not write it)"
        exit 1
    fi
done
if ! cmp -s target/cluster_digest_1.txt target/cluster_digest_2.txt; then
    echo "FAIL: cluster response digests differ between shards=1 and shards=2"
    diff target/cluster_digest_1.txt target/cluster_digest_2.txt || true
    exit 1
fi
echo "cluster digests identical across shard counts (1 vs 2):"
cat target/cluster_digest_1.txt

# Session determinism: the same conversation served straight-through
# (one request) and served as prefill+suspend on one shard / resume on a
# DIFFERENT shard (the suspending shard is retired in between) must
# digest identically — generated tokens and prompt log-prob bits. A
# mismatch means the snapshot/restore path perturbed the recurrent
# state or the carried log-prob accounting.
echo "== session determinism (straight-through vs cross-shard suspend/resume) =="
rm -f target/session_digest_straight.txt target/session_digest_resume.txt
RBTW_SESSION_DIGEST=target/session_digest_straight.txt \
    RBTW_SESSION_MODE=straight \
    cargo test -q --test session_integration session_digest_is_path_invariant
RBTW_SESSION_DIGEST=target/session_digest_resume.txt \
    RBTW_SESSION_MODE=resume \
    cargo test -q --test session_integration session_digest_is_path_invariant
for f in target/session_digest_straight.txt target/session_digest_resume.txt; do
    if [ ! -s "$f" ]; then
        echo "FAIL: $f missing or empty (session digest test did not write it)"
        exit 1
    fi
done
if ! cmp -s target/session_digest_straight.txt target/session_digest_resume.txt; then
    echo "FAIL: suspend/resume digest differs from straight-through serve"
    diff target/session_digest_straight.txt target/session_digest_resume.txt || true
    exit 1
fi
echo "session digests identical (straight-through vs cross-shard resume):"
cat target/session_digest_straight.txt

# Front-door smoke: a real `rbtw serve --listen` process on an ephemeral
# loopback port, driven by the netclient example over TCP, must produce a
# greedy digest BIT-IDENTICAL to the same load served in-process (no
# sockets). The wire carries prompt log-probs as raw f64 bits, so one
# flipped token or mantissa bit anywhere in the framing/pump path splits
# the digests. `--drain` ends the server gracefully; a hung server trips
# the timeout.
echo "== front door smoke (wire digest vs in-process digest) =="
cargo build --release --example netclient
rm -f target/frontdoor_server.log
./target/release/rbtw serve synthetic --listen 127.0.0.1:0 \
    --shards 2 --slots 4 > target/frontdoor_server.log < /dev/null &
SRV=$!
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's/^listening on //p' target/frontdoor_server.log | head -n1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$SRV" 2>/dev/null; then
        echo "FAIL: serve --listen exited before binding:"
        cat target/frontdoor_server.log
        exit 1
    fi
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "FAIL: serve --listen never printed its address:"
    cat target/frontdoor_server.log
    kill "$SRV" 2>/dev/null || true
    exit 1
fi
WIRE_OUT=$(timeout 120 ./target/release/examples/netclient \
    --connect "$ADDR" --drain)
if ! wait "$SRV"; then
    echo "FAIL: serve --listen exited non-zero after drain:"
    cat target/frontdoor_server.log
    exit 1
fi
LOCAL_OUT=$(timeout 120 ./target/release/examples/netclient --local \
    --shards 2 --slots 4)
WIRE_DIGEST=$(printf '%s\n' "$WIRE_OUT" | sed -n 's/^greedy://p')
LOCAL_DIGEST=$(printf '%s\n' "$LOCAL_OUT" | sed -n 's/^greedy://p')
if [ -z "$WIRE_DIGEST" ] || [ -z "$LOCAL_DIGEST" ]; then
    echo "FAIL: netclient did not print a greedy digest"
    printf 'wire:\n%s\nlocal:\n%s\n' "$WIRE_OUT" "$LOCAL_OUT"
    exit 1
fi
if [ "$WIRE_DIGEST" != "$LOCAL_DIGEST" ]; then
    echo "FAIL: wire digest $WIRE_DIGEST != in-process digest $LOCAL_DIGEST"
    echo "      (the TCP front door perturbed a greedy response)"
    exit 1
fi
echo "front-door digest identical over TCP and in-process: $WIRE_DIGEST"
# the wire run also exercises the session/resume verbs (suspend under a
# session id, resume with a continuation) before the greedy stream
if ! printf '%s\n' "$WIRE_OUT" | grep -q '^session-roundtrip: ok'; then
    echo "FAIL: wire session/resume round-trip did not report ok:"
    printf '%s\n' "$WIRE_OUT"
    exit 1
fi
echo "wire session/resume round-trip ok"

# Chaos gate 1: the SAME wire load served while a seeded fault plan
# kills a shard worker mid-load. Supervision must contain the panic,
# respawn the engine from the shared packed weights, and replay the
# dead generation's in-flight requests — so the greedy digest must be
# BIT-IDENTICAL to the fault-free in-process digest above, with zero
# accepted requests lost. `--expect-respawn` additionally asserts via
# /metrics that the crash actually happened (a gate that silently
# stops injecting faults must fail, not pass vacuously).
echo "== chaos gate (scripted shard crash must be digest-invisible) =="
rm -f target/chaos_server.log
RBTW_FAULT_PLAN="panic:shard=1,step=20" \
    ./target/release/rbtw serve synthetic --listen 127.0.0.1:0 \
    --shards 2 --slots 4 > target/chaos_server.log < /dev/null &
SRV=$!
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's/^listening on //p' target/chaos_server.log | head -n1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$SRV" 2>/dev/null; then
        echo "FAIL: chaos serve exited before binding:"
        cat target/chaos_server.log
        exit 1
    fi
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "FAIL: chaos serve never printed its address:"
    cat target/chaos_server.log
    kill "$SRV" 2>/dev/null || true
    exit 1
fi
CHAOS_OUT=$(timeout 120 ./target/release/examples/netclient \
    --connect "$ADDR" --expect-respawn --drain)
if ! wait "$SRV"; then
    echo "FAIL: chaos serve exited non-zero after drain:"
    cat target/chaos_server.log
    exit 1
fi
CHAOS_DIGEST=$(printf '%s\n' "$CHAOS_OUT" | sed -n 's/^greedy://p')
RESPAWNS=$(printf '%s\n' "$CHAOS_OUT" | sed -n 's/^respawns: //p')
if [ -z "$CHAOS_DIGEST" ] || [ -z "$RESPAWNS" ]; then
    echo "FAIL: chaos netclient did not report a digest + respawn count:"
    printf '%s\n' "$CHAOS_OUT"
    exit 1
fi
if [ "$CHAOS_DIGEST" != "$LOCAL_DIGEST" ]; then
    echo "FAIL: chaos digest $CHAOS_DIGEST != fault-free digest $LOCAL_DIGEST"
    echo "      (a respawned shard perturbed a greedy response)"
    exit 1
fi
echo "mid-load shard crash invisible in the digest ($RESPAWNS respawn(s)): $CHAOS_DIGEST"

# Chaos gate 2: a fault plan that flips one packed plane bit during the
# load models a corrupt checkpoint. The integrity check must refuse to
# serve — non-zero exit with a typed fingerprint error — never start
# with silently wrong logits.
echo "== chaos gate (corrupt plane word must refuse to load) =="
rm -f target/corrupt_server.log
set +e
RBTW_FAULT_PLAN="flip:matrix=0,word=0,bit=5" \
    timeout 60 ./target/release/rbtw serve synthetic \
    --listen 127.0.0.1:0 --shards 1 --slots 2 \
    > target/corrupt_server.log 2>&1 < /dev/null
CORRUPT_RC=$?
set -e
if [ "$CORRUPT_RC" -eq 0 ]; then
    echo "FAIL: serving a corrupted model succeeded (must refuse to load):"
    cat target/corrupt_server.log
    exit 1
fi
if ! grep -qi 'fingerprint' target/corrupt_server.log; then
    echo "FAIL: corrupt load refused without a typed fingerprint error:"
    cat target/corrupt_server.log
    exit 1
fi
echo "corrupt plane word refused with a typed fingerprint error (exit $CORRUPT_RC)"

# Traced-serve gate: the SAME wire load served with the flight recorder
# armed (--trace) must produce a greedy digest BIT-IDENTICAL to the
# untraced in-process digest above — observability is provably
# non-perturbing or it fails here. The run must also leave a non-empty,
# parseable Chrome trace with real spans (`rbtw trace-check`), so the
# gate cannot pass vacuously by recording nothing.
echo "== traced-serve gate (tracing must be digest-invisible) =="
rm -f target/trace_server.log target/trace_server.json
./target/release/rbtw serve synthetic --listen 127.0.0.1:0 \
    --shards 2 --slots 4 --trace --trace-out target/trace_server.json \
    > target/trace_server.log < /dev/null &
SRV=$!
ADDR=""
for _ in $(seq 1 100); do
    ADDR=$(sed -n 's/^listening on //p' target/trace_server.log | head -n1)
    [ -n "$ADDR" ] && break
    if ! kill -0 "$SRV" 2>/dev/null; then
        echo "FAIL: traced serve exited before binding:"
        cat target/trace_server.log
        exit 1
    fi
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "FAIL: traced serve never printed its address:"
    cat target/trace_server.log
    kill "$SRV" 2>/dev/null || true
    exit 1
fi
TRACED_OUT=$(timeout 120 ./target/release/examples/netclient \
    --connect "$ADDR" --drain)
if ! wait "$SRV"; then
    echo "FAIL: traced serve exited non-zero after drain:"
    cat target/trace_server.log
    exit 1
fi
TRACED_DIGEST=$(printf '%s\n' "$TRACED_OUT" | sed -n 's/^greedy://p')
if [ -z "$TRACED_DIGEST" ]; then
    echo "FAIL: traced netclient did not print a greedy digest:"
    printf '%s\n' "$TRACED_OUT"
    exit 1
fi
if [ "$TRACED_DIGEST" != "$LOCAL_DIGEST" ]; then
    echo "FAIL: traced digest $TRACED_DIGEST != untraced digest $LOCAL_DIGEST"
    echo "      (--trace perturbed a greedy response)"
    exit 1
fi
echo "tracing digest-invisible over the wire: $TRACED_DIGEST"
if [ ! -s target/trace_server.json ]; then
    echo "FAIL: traced serve wrote no trace file:"
    cat target/trace_server.log
    exit 1
fi
./target/release/rbtw trace-check target/trace_server.json

# Helper for the datapath gates below: start `rbtw serve synthetic
# --listen` with the given extra flags, drive the standard netclient
# load over the wire, and print the greedy digest on stdout. Failures
# report on stderr and return non-zero (which aborts the script when
# called via command substitution in an assignment).
serve_wire_digest() {
    local log="$1"; shift
    rm -f "$log"
    ./target/release/rbtw serve synthetic --listen 127.0.0.1:0 \
        "$@" > "$log" < /dev/null &
    local srv=$!
    local addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/^listening on //p' "$log" | head -n1)
        [ -n "$addr" ] && break
        if ! kill -0 "$srv" 2>/dev/null; then
            echo "FAIL: serve $* exited before binding:" >&2
            cat "$log" >&2
            return 1
        fi
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "FAIL: serve $* never printed its address:" >&2
        cat "$log" >&2
        kill "$srv" 2>/dev/null || true
        return 1
    fi
    local out
    if ! out=$(timeout 120 ./target/release/examples/netclient \
        --connect "$addr" --drain); then
        echo "FAIL: netclient failed against serve $*" >&2
        cat "$log" >&2
        return 1
    fi
    if ! wait "$srv"; then
        echo "FAIL: serve $* exited non-zero after drain:" >&2
        cat "$log" >&2
        return 1
    fi
    printf '%s\n' "$out" | sed -n 's/^greedy://p'
}

# Datapath gate 1: `--datapath f32` is the documented exact escape
# hatch — its wire digest must be BIT-IDENTICAL to the flag-free
# in-process digest above. Any drift means the datapath plumbing
# perturbed the default path.
echo "== datapath gate (--datapath f32 must match the default digest) =="
F32_DIGEST=$(serve_wire_digest target/datapath_f32_server.log \
    --shards 2 --slots 4 --datapath f32)
if [ -z "$F32_DIGEST" ]; then
    echo "FAIL: --datapath f32 serve produced no greedy digest"
    exit 1
fi
if [ "$F32_DIGEST" != "$LOCAL_DIGEST" ]; then
    echo "FAIL: --datapath f32 digest $F32_DIGEST != default $LOCAL_DIGEST"
    echo "      (the f32 datapath must be bit-identical to no flag at all)"
    exit 1
fi
echo "--datapath f32 digest identical to the default build: $F32_DIGEST"

# Datapath gate 2: the xnor datapath changes logits by design, so there
# is no f32 reference digest — instead its digest must be
# SELF-CONSISTENT: identical across thread counts {1, 4} and shard
# counts {1, 2}. A split means the quantized accumulators leaked
# scheduling or column-sharding into the logits.
echo "== datapath gate (xnor digest invariant across threads x shards) =="
XNOR_REF=""
for threads in 1 4; do
    for shards in 1 2; do
        DGST=$(serve_wire_digest \
            "target/datapath_xnor_t${threads}_s${shards}.log" \
            --shards "$shards" --slots 4 --threads "$threads" \
            --datapath xnor)
        if [ -z "$DGST" ]; then
            echo "FAIL: xnor serve (threads=$threads shards=$shards)" \
                 "produced no greedy digest"
            exit 1
        fi
        if [ -z "$XNOR_REF" ]; then
            XNOR_REF="$DGST"
        elif [ "$DGST" != "$XNOR_REF" ]; then
            echo "FAIL: xnor digest $DGST (threads=$threads" \
                 "shards=$shards) != $XNOR_REF"
            echo "      (the xnor datapath must be thread- and" \
                 "shard-invariant)"
            exit 1
        fi
    done
done
if [ "$XNOR_REF" = "$LOCAL_DIGEST" ]; then
    echo "FAIL: xnor digest equals the f32 digest — the xnor datapath"
    echo "      never engaged (the gate would be vacuous)"
    exit 1
fi
echo "xnor digest stable across threads {1,4} x shards {1,2}: $XNOR_REF"

# Bench-regression gate: re-measure the GEMM kernel bench and diff the
# tracked throughput/latency keys against the stored baseline
# (`rbtw bench-diff` exits non-zero past the tolerance; see
# RBTW_BENCH_TOLERANCE). First run on a host has no baseline: the gate
# skips cleanly and stores this run as the baseline for the next one.
echo "== bench-regression gate (quant_gemm kernels) =="
cargo bench --bench quant_gemm
BENCH_BASELINE=target/bench_baseline/BENCH_gemm_kernels.json
if [ -s "$BENCH_BASELINE" ]; then
    ./target/release/rbtw bench-diff "$BENCH_BASELINE" \
        BENCH_gemm_kernels.json
else
    echo "no stored baseline — saving this run to $BENCH_BASELINE \
(regression diff starts next run)"
    mkdir -p target/bench_baseline
    cp BENCH_gemm_kernels.json "$BENCH_BASELINE"
fi

# Same gate for the end-to-end serving bench: per-backend throughput
# rows (per-slot vs batched, thread/layer sweep) diffed against the
# stored baseline. Identity-keyed row matching in bench-diff means a
# new backend/datapath row in either report is skipped, not mispaired.
echo "== bench-regression gate (serve_backends throughput) =="
cargo bench --bench serve_backends
SERVE_BASELINE=target/bench_baseline/BENCH_serve_backends.json
if [ -s "$SERVE_BASELINE" ]; then
    ./target/release/rbtw bench-diff "$SERVE_BASELINE" \
        BENCH_serve_backends.json
else
    echo "no stored baseline — saving this run to $SERVE_BASELINE \
(regression diff starts next run)"
    mkdir -p target/bench_baseline
    cp BENCH_serve_backends.json "$SERVE_BASELINE"
fi

# The seed code predates rustfmt; keep the check advisory unless
# RBTW_CI_STRICT_FMT=1 (flip once the tree is formatted).
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    if ! cargo fmt --check; then
        if [ "${RBTW_CI_STRICT_FMT:-0}" = "1" ]; then
            exit 1
        fi
        echo "(fmt drift reported above — advisory; set RBTW_CI_STRICT_FMT=1 to enforce)"
    fi
else
    echo "== cargo fmt --check skipped (rustfmt not installed) =="
fi

echo "CI OK"
