#!/usr/bin/env bash
# Tier-1 verification (offline): build, test, and (when rustfmt is
# installed) check formatting. Run from anywhere; works without network —
# all dependencies are vendored path crates (see Cargo.toml).
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The seed code predates rustfmt; keep the check advisory unless
# RBTW_CI_STRICT_FMT=1 (flip once the tree is formatted).
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    if ! cargo fmt --check; then
        if [ "${RBTW_CI_STRICT_FMT:-0}" = "1" ]; then
            exit 1
        fi
        echo "(fmt drift reported above — advisory; set RBTW_CI_STRICT_FMT=1 to enforce)"
    fi
else
    echo "== cargo fmt --check skipped (rustfmt not installed) =="
fi

echo "CI OK"
