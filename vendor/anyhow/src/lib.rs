//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the exact surface the `rbtw` crate uses: an [`Error`] type
//! carrying a context chain, `Result<T>`, the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Semantics match upstream anyhow for that subset: `{e}` prints the
//! outermost context, `{e:#}` prints the whole chain joined by ": ".

use std::fmt;

/// An error carrying a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (root cause last).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err()
            .context("starting up");
        assert_eq!(format!("{e}"), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: reading config: file missing");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big");
        let name = "wx";
        let e = anyhow!("missing param {name}");
        assert_eq!(format!("{e}"), "missing param wx");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
