//! Offline stub of the `xla` (xla-rs) bindings used by `rbtw::runtime`.
//!
//! The container has no libxla/PJRT shared objects, so this crate keeps
//! the crate graph buildable and the *host-side* half of the API fully
//! functional: [`Literal`] really stores typed array data (create,
//! `to_vec`, `get_first_element`, `element_count` all work), which is
//! enough for artifact init-value loading, checkpointing and the packed
//! deployment engine — everything except running compiled HLO.
//!
//! The *device-side* half (PJRT compile/execute) returns a descriptive
//! error at the first `compile` call. The `rbtw::engine` packed backends
//! never reach it; only the `PjrtDense` backend and the train/eval paths
//! need a real PJRT build.

use std::fmt;

/// Error type for stubbed XLA operations.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT unavailable: built against the offline xla stub \
                        (packed engine backends remain fully functional)";

/// Element dtype of a literal (the subset the AOT boundary uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

impl ElementType {
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Host types that can view literal data.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn from_le(b: [u8; 4]) -> Self {
        u32::from_le_bytes(b)
    }
}

/// A host-side typed array. Fully functional in the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let elements: usize = dims.iter().product::<usize>().max(1);
        if untyped_data.len() != elements * ty.size_bytes() {
            return Err(Error::new(format!(
                "literal data size {} does not match shape {:?} ({} bytes expected)",
                untyped_data.len(),
                dims,
                elements * ty.size_bytes()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: untyped_data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::new(format!(
                "literal type mismatch: stored {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::new("empty literal"))
    }

    /// Split a tuple literal into its leaves. The stub never constructs
    /// tuples (they only come back from PJRT execution), so this errors.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::new(STUB_MSG))
    }
}

/// Parsed HLO module text. The stub records the source path and verifies
/// the file is readable so missing artifacts fail with a precise error.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::metadata(path) {
            Ok(_) => Ok(HloModuleProto { path: path.to_string() }),
            Err(e) => Err(Error::new(format!("reading HLO text {path}: {e}"))),
        }
    }
}

/// An XLA computation handle.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// PJRT client handle. Creation succeeds (cheap) so artifact metadata and
/// init values can be loaded; compilation is where the stub stops.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub, no PJRT)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_MSG))
    }
}

/// Compiled executable handle (never actually constructed by the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }
}

/// Device buffer handle (never actually constructed by the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(STUB_MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data)
            .unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.0]);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_literal() {
        let data = 7i32.to_le_bytes().to_vec();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[], &data)
            .unwrap();
        assert_eq!(lit.element_count(), 1);
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn pjrt_is_stubbed() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { path: "x".into() };
        assert!(client.compile(&comp).is_err());
    }
}
