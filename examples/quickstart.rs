//! Quickstart: the 60-second tour of the rbtw stack.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the ternary char-LM artifact, takes a few optimizer steps on the
//! synthetic PTB-like corpus, evaluates, and exports the packed
//! deployment weights — touching every layer: data pipeline → PJRT
//! train/eval executables → bit-packed export.

use std::path::PathBuf;

use rbtw::coordinator::{Split, TrainSpec, Trainer};
use rbtw::model::export_packed;
use rbtw::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    anyhow::ensure!(dir.join("char_ptb_ter.meta.json").exists(),
                    "run `make artifacts` first");
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());

    let spec = TrainSpec { steps: 60, lr: 5e-3, eval_every: 20,
                           eval_batches: 2, ..TrainSpec::default() };
    let mut trainer = Trainer::new(&engine, &dir, "char_ptb_ter", spec)?;
    println!("training char_ptb_ter (BN-LSTM, stochastic ternary weights)…");
    let report = trainer.run()?;
    println!("  first loss {:.3} → last loss {:.3} nats",
             report.train_loss.points[0].1,
             report.train_loss.last().unwrap());

    let ev = trainer.evaluate(Split::Test, 4)?;
    println!("  test bpc {:.3}", ev.metric);

    let packed = export_packed(&trainer.sess, 0xC0FFEE)?;
    let fp32: usize = packed.matrices.values()
        .map(|m| { let (r, c) = m.dims(); r * c * 4 }).sum();
    println!("  packed deployment weights: {} B (vs {} B fp32, {:.1}x)",
             packed.total_bytes(), fp32,
             fp32 as f64 / packed.total_bytes() as f64);
    Ok(())
}
