//! Hardware design-space exploration over the §6 accelerator model.
//!
//!   cargo run --release --example hw_explore
//!
//! Sweeps MAC-array sizes and precisions, printing the area/power/latency
//! frontier for the char-PTB workload plus the paper's two published
//! design points, and shows where the compute-bound → memory-bound
//! crossover falls as DRAM bandwidth shrinks.

use std::time::Instant;

use rbtw::engine::{self, BackendKind, BackendSpec, InferBackend, ModelWeights};
use rbtw::hwsim::{high_speed_design, paper_workloads, simulate_timestep,
                  synthesize, timestep_latency, HwConfig, Precision};
use rbtw::util::table::Table;

fn main() {
    let w = &paper_workloads()[0]; // char-PTB LSTM h=1000
    println!("workload: {} (LSTM h={}, d_in={})\n", w.name, w.hidden, w.d_in);

    println!("== lane-count sweep ==");
    let mut t = Table::new(&["precision", "# MAC", "area mm2", "power mW",
                             "latency us", "util %"]);
    for prec in [Precision::Fixed12, Precision::Binary, Precision::Ternary] {
        for lanes in [100usize, 200, 500, 1000, 2000] {
            let cfg = HwConfig { mac_units: lanes, ..HwConfig::low_power(prec) };
            let syn = synthesize(&cfg);
            let p = timestep_latency(&cfg, w);
            t.row(&[
                prec.label().into(),
                lanes.to_string(),
                format!("{:.2}", syn.area_mm2),
                format!("{:.0}", syn.power_mw),
                format!("{:.1}", p.latency_us),
                format!("{:.0}", p.stats.utilization * 100.0),
            ]);
        }
    }
    t.print();

    println!("\n== paper design points ==");
    let fp = HwConfig::low_power(Precision::Fixed12);
    let mut t2 = Table::new(&["design", "precision", "latency us", "speedup"]);
    let base = timestep_latency(&fp, w).latency_us;
    for prec in [Precision::Fixed12, Precision::Binary, Precision::Ternary] {
        for (label, cfg) in [("low-power", HwConfig::low_power(prec)),
                             ("high-speed", high_speed_design(prec, &fp))] {
            let l = timestep_latency(&cfg, w).latency_us;
            t2.row(&[label.into(), prec.label().into(),
                     format!("{l:.1}"), format!("{:.1}x", base / l)]);
        }
    }
    t2.print();

    println!("\n== bandwidth sensitivity (binary high-speed) ==");
    let mut t3 = Table::new(&["dram GB/s", "compute us", "dram us", "bound"]);
    for gbps in [256.0, 128.0, 64.0, 25.6, 12.8, 6.4] {
        let cfg = HwConfig { dram_gbps: gbps,
                             ..high_speed_design(Precision::Binary, &fp) };
        let s = simulate_timestep(&cfg, w.cell, w.d_in, w.hidden, w.layers);
        let (cu, du) = (s.time_us(&cfg), s.dram_time_us(&cfg));
        t3.row(&[format!("{gbps}"), format!("{cu:.1}"), format!("{du:.1}"),
                 (if du > cu { "memory" } else { "compute" }).into()]);
    }
    t3.print();

    // the same workload on the software engine backends: the CPU
    // realization of the mux-datapath, measured through the serving API.
    println!("\n== software engine backends (measured, single stream, \
              h={} ternary) ==", w.hidden);
    let mut t4 = Table::new(&["backend", "us/step", "steps/s", "weights B"]);
    let weights = ModelWeights::synthetic(w.d_in, w.hidden, "ter", 0xD0E);
    for kind in BackendKind::all() {
        let backend = match engine::from_weights(
            &weights, &BackendSpec::with(kind, 1, 5)) {
            Ok(b) => b,
            Err(_) => {
                t4.row(&[kind.label().into(), "-".into(),
                         "needs artifact+PJRT".into(), "-".into()]);
                continue;
            }
        };
        let mut backend = backend;
        let vocab = backend.vocab();
        let mut logits = vec![0.0f32; vocab];
        backend.reset_slot(0).unwrap();
        let steps = 2_000usize;
        let t0 = Instant::now();
        for i in 0..steps {
            backend
                .step_batch(&[Some((i % vocab) as i32)], &mut logits)
                .unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        t4.row(&[
            kind.label().into(),
            format!("{:.1}", dt / steps as f64 * 1e6),
            format!("{:.0}", steps as f64 / dt),
            backend.weight_bytes().to_string(),
        ]);
    }
    t4.print();
    println!("(compare the us/step orderings with the simulated design \
              points above — both realize the paper's multiplier-free \
              datapath, in silicon vs in SW)");
}
