//! Serving example: the same continuous-batching server driven over
//! every engine backend — dense PJRT executable vs the packed
//! binary/ternary CPU engines — through one `InferBackend` interface,
//! plus the sharded serving cluster over one shared weight set.
//!
//!   cargo run --release --example serve_lm [-- --backend pjrt|packed|planes|all]
//!       [--requests N] [--artifact NAME] [--per-slot] [--threads N]
//!       [--shards N] [--policy least-loaded|round-robin]
//!       [--arch lstm|gru] [--layers N]
//!
//! `--per-slot` steps the packed backends through the per-slot GEMV
//! reference path instead of the default batched SIMD-tiled GEMM (one
//! weight stream per step for all active slots); `--threads N` pins the
//! batched path's worker-pool size (0 = one per core, the default).
//! Logits are bit-identical for every path and thread count, only
//! tokens/sec changes. `--arch`/`--layers` pick the synthetic stand-in
//! model's cell architecture and stack depth (artifacts carry their
//! own shape), so deep LSTM and GRU packed serving run end-to-end
//! offline.
//!
//! `--shards N` (default 1) additionally serves the packed kinds
//! through a `ServingCluster`: N engine shards — each its own
//! continuous-batching server on its own thread — fed by one async
//! router over ONE shared copy of the packed planes. Greedy responses
//! are bit-identical to the single server; resident weight bytes stay
//! constant as shards grow.
//!
//! With artifacts built (`make artifacts`) the chosen artifact's init
//! weights are served; without them a synthetic ternary BN-LSTM stands
//! in so the packed deployment path still runs end-to-end. The packed
//! backends never construct a PJRT session.

use std::path::PathBuf;

use rbtw::cluster::{run_cluster_load, RoutePolicy};
use rbtw::coordinator::{run_load, LoadSpec};
use rbtw::engine::{self, BackendKind, BackendSpec, CellArch, InferBackend,
                   ModelWeights, SharedModel};
use rbtw::util::table::Table;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = flag(&args, "--requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(48)
        .max(1);
    let artifact = flag(&args, "--artifact").unwrap_or("char_ptb_ter".into());
    let backend_arg = flag(&args, "--backend").unwrap_or("all".into());
    let per_slot = args.iter().any(|a| a == "--per-slot");
    let threads: usize = match flag(&args, "--threads") {
        Some(s) => s.parse().map_err(|_| anyhow::anyhow!(
            "--threads takes a non-negative integer (0 = auto), got '{s}'"))?,
        None => 0,
    };
    let shards: usize = match flag(&args, "--shards") {
        Some(s) => s.parse().map_err(|_| anyhow::anyhow!(
            "--shards takes a positive integer, got '{s}'"))?,
        None => 1,
    };
    let policy = match flag(&args, "--policy") {
        Some(p) => RoutePolicy::parse(&p)?,
        None => RoutePolicy::LeastLoaded,
    };
    let arch = match flag(&args, "--arch") {
        Some(a) => CellArch::parse(&a)?,
        None => CellArch::Lstm,
    };
    let layers: usize = match flag(&args, "--layers") {
        Some(s) => match s.parse() {
            Ok(n) if (1..=BackendSpec::MAX_LAYERS).contains(&n) => n,
            _ => anyhow::bail!(
                "--layers takes an integer in [1, {}], got '{s}'",
                BackendSpec::MAX_LAYERS),
        },
        None => 1,
    };
    let kinds: Vec<BackendKind> = if backend_arg == "all" {
        BackendKind::all().to_vec()
    } else {
        vec![BackendKind::parse(&backend_arg)?]
    };

    let dir = PathBuf::from("artifacts");
    let have_artifact = dir.join(format!("{artifact}.meta.json")).exists();
    let synthetic =
        ModelWeights::synthetic_arch(50, 128, arch, layers, "ter", 0xA11CE);
    if !have_artifact {
        println!("(artifact {artifact} not built — serving the synthetic \
                  stand-in model {}: {} x{} layer(s))\n",
                 synthetic.name, arch.label(), layers);
    }

    let mut t = Table::new(&["backend", "gemm", "thr", "req", "tok/s",
                             "p50 ms", "p95 ms", "p99 ms", "weights B"]);
    for kind in kinds.iter().copied() {
        let mut spec = BackendSpec::with(kind, 16, 3)
            .with_threads(threads)
            .with_arch(arch, layers);
        if per_slot {
            spec = spec.per_slot();
        }
        let backend = if have_artifact {
            engine::open(&dir, &artifact, &spec)
        } else {
            engine::from_weights(&synthetic, &spec)
        };
        let backend = match backend {
            Ok(b) => b,
            Err(e) => {
                println!("  {} unavailable: {e:#}", kind.label());
                continue;
            }
        };
        let weight_bytes = backend.weight_bytes();
        let load = LoadSpec { n_requests, ..LoadSpec::default() };
        let report = match run_load(backend, &load) {
            Ok(r) => r,
            Err(e) => {
                println!("  {} failed mid-serve: {e:#}", kind.label());
                continue;
            }
        };
        // PjrtDense batches natively inside the executable; the
        // batch-gemm flag only selects a path on the packed backends.
        let gemm_label = if kind == BackendKind::PjrtDense {
            "native"
        } else if per_slot {
            "per-slot"
        } else {
            "batched"
        };
        let thr_label = if kind == BackendKind::PjrtDense || per_slot {
            "-".to_string()
        } else {
            spec.threads_resolved().to_string()
        };
        t.row(&[
            kind.label().into(),
            gemm_label.into(),
            thr_label,
            report.responses.len().to_string(),
            format!("{:.0}", report.tokens_per_sec()),
            format!("{:.1}", report.total.p50_ms),
            format!("{:.1}", report.total.p95_ms),
            format!("{:.1}", report.total.p99_ms),
            weight_bytes.to_string(),
        ]);
    }
    println!("== continuous-batching server, one InferBackend interface ==");
    t.print();
    println!("\n(packed rows hold weights at 1-2 bits each — the paper's \
              12x deployment memory saving; pjrt-dense needs a real PJRT \
              build and compiled artifacts)");

    if shards > 1 {
        println!("\n== serving cluster: {shards} shards, {policy} routing, \
                  one shared weight set ==");
        let mut ct = Table::new(&["backend", "shards", "req", "tok/s",
                                  "p50 ms", "p95 ms", "p99 ms",
                                  "weights B (resident)"]);
        for kind in [BackendKind::PackedCpu, BackendKind::PackedPlanes] {
            if !kinds.contains(&kind) {
                continue;
            }
            let spec = BackendSpec::with(kind, 16, 3)
                .with_threads(threads)
                .with_shards(shards)
                .with_arch(arch, layers);
            let shared = if have_artifact {
                let w = ModelWeights::from_artifact(&dir, &artifact)?;
                SharedModel::prepare(&w, kind, spec.sample_seed)?
            } else {
                SharedModel::prepare(&synthetic, kind, spec.sample_seed)?
            };
            let load = LoadSpec { n_requests, ..LoadSpec::default() };
            let report = match run_cluster_load(&shared, &spec, policy,
                                                load.n_requests, &load) {
                Ok(r) => r,
                Err(e) => {
                    println!("  {} cluster failed: {e:#}", kind.label());
                    continue;
                }
            };
            ct.row(&[
                kind.label().into(),
                shards.to_string(),
                report.stats.completed.to_string(),
                format!("{:.0}", report.tokens_per_sec()),
                format!("{:.1}", report.stats.total.p50_ms),
                format!("{:.1}", report.stats.total.p95_ms),
                format!("{:.1}", report.stats.total.p99_ms),
                shared.weight_bytes().to_string(),
            ]);
        }
        ct.print();
        println!("\n(every shard aliases the same Arc-backed plane bytes: \
                  the resident column does not grow with shards)");
    }
    Ok(())
}
