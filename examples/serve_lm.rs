//! Serving example: the same continuous-batching server driven over
//! every engine backend — dense PJRT executable vs the packed
//! binary/ternary CPU engines — through one `InferBackend` interface.
//!
//!   cargo run --release --example serve_lm [-- --backend pjrt|packed|planes|all]
//!       [--requests N] [--artifact NAME] [--per-slot] [--threads N]
//!
//! `--per-slot` steps the packed backends through the per-slot GEMV
//! reference path instead of the default batched SIMD-tiled GEMM (one
//! weight stream per step for all active slots); `--threads N` pins the
//! batched path's worker-pool size (0 = one per core, the default).
//! Logits are bit-identical for every path and thread count, only
//! tokens/sec changes.
//!
//! With artifacts built (`make artifacts`) the chosen artifact's init
//! weights are served; without them a synthetic ternary BN-LSTM stands
//! in so the packed deployment path still runs end-to-end. The packed
//! backends never construct a PJRT session.

use std::path::PathBuf;

use rbtw::coordinator::{run_load, LoadSpec};
use rbtw::engine::{self, BackendKind, BackendSpec, InferBackend, ModelWeights};
use rbtw::util::stats::percentiles;
use rbtw::util::table::Table;

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = flag(&args, "--requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(48)
        .max(1);
    let artifact = flag(&args, "--artifact").unwrap_or("char_ptb_ter".into());
    let backend_arg = flag(&args, "--backend").unwrap_or("all".into());
    let per_slot = args.iter().any(|a| a == "--per-slot");
    let threads: usize = match flag(&args, "--threads") {
        Some(s) => s.parse().map_err(|_| anyhow::anyhow!(
            "--threads takes a non-negative integer (0 = auto), got '{s}'"))?,
        None => 0,
    };
    let kinds: Vec<BackendKind> = if backend_arg == "all" {
        BackendKind::all().to_vec()
    } else {
        vec![BackendKind::parse(&backend_arg)?]
    };

    let dir = PathBuf::from("artifacts");
    let have_artifact = dir.join(format!("{artifact}.meta.json")).exists();
    let synthetic = ModelWeights::synthetic(50, 128, "ter", 0xA11CE);
    if !have_artifact {
        println!("(artifact {artifact} not built — serving the synthetic \
                  stand-in model {})\n", synthetic.name);
    }

    let mut t = Table::new(&["backend", "gemm", "thr", "req", "tok/s",
                             "p50 ms", "p99 ms", "peak batch", "weights B"]);
    for kind in kinds {
        let mut spec = BackendSpec::with(kind, 16, 3).with_threads(threads);
        if per_slot {
            spec = spec.per_slot();
        }
        let backend = if have_artifact {
            engine::open(&dir, &artifact, &spec)
        } else {
            engine::from_weights(&synthetic, &spec)
        };
        let backend = match backend {
            Ok(b) => b,
            Err(e) => {
                println!("  {} unavailable: {e:#}", kind.label());
                continue;
            }
        };
        let weight_bytes = backend.weight_bytes();
        let load = LoadSpec { n_requests, ..LoadSpec::default() };
        let (responses, stats, wall) = match run_load(backend, &load) {
            Ok(r) => r,
            Err(e) => {
                println!("  {} failed mid-serve: {e:#}", kind.label());
                continue;
            }
        };
        let lat: Vec<f64> = responses
            .iter()
            .map(|r| (r.queue_time + r.run_time).as_secs_f64() * 1e3)
            .collect();
        let ps = percentiles(&lat, &[0.5, 0.99]);
        // PjrtDense batches natively inside the executable; the
        // batch-gemm flag only selects a path on the packed backends.
        let gemm_label = if kind == BackendKind::PjrtDense {
            "native"
        } else if per_slot {
            "per-slot"
        } else {
            "batched"
        };
        let thr_label = if kind == BackendKind::PjrtDense || per_slot {
            "-".to_string()
        } else {
            spec.threads_resolved().to_string()
        };
        t.row(&[
            kind.label().into(),
            gemm_label.into(),
            thr_label,
            responses.len().to_string(),
            format!("{:.0}", stats.tokens_processed as f64 / wall),
            format!("{:.1}", ps[0]),
            format!("{:.1}", ps[1]),
            stats.peak_active_slots.to_string(),
            weight_bytes.to_string(),
        ]);
    }
    println!("== continuous-batching server, one InferBackend interface ==");
    t.print();
    println!("\n(packed rows hold weights at 1-2 bits each — the paper's \
              12x deployment memory saving; pjrt-dense needs a real PJRT \
              build and compiled artifacts)");
    Ok(())
}
