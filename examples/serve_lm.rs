//! Serving example: batched request serving over the AOT Pallas-cell
//! executable, with latency/throughput reporting — plus the packed
//! popcount engine as the "ASIC-style" single-stream comparison.
//!
//!   cargo run --release --example serve_lm [n_requests]

use std::path::PathBuf;
use std::time::Instant;

use rbtw::coordinator::{InferenceServer, Request};
use rbtw::quant::PackedLstmCell;
use rbtw::runtime::{Engine, Session};
use rbtw::util::stats::percentiles;
use rbtw::util::table::Table;
use rbtw::util::Rng;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args().nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(48);
    let dir = PathBuf::from("artifacts");
    let engine = Engine::cpu()?;
    let mut rng = Rng::new(17);
    let mut t = Table::new(&["artifact", "req", "tok/s", "p50 ms", "p99 ms",
                             "peak batch"]);

    for artifact in ["char_ptb_fp", "char_ptb_bin", "char_ptb_ter"] {
        let mut server = InferenceServer::open(&engine, &dir, artifact,
                                               n_requests)?;
        for id in 0..n_requests as u64 {
            server.submit(Request {
                id,
                prompt: (0..12).map(|_| rng.below(50) as i32).collect(),
                gen_len: 24,
                temperature: 0.8,
            })?;
        }
        let t0 = Instant::now();
        let responses = server.pump(1_000_000)?;
        let wall = t0.elapsed().as_secs_f64();
        let lat: Vec<f64> = responses.iter()
            .map(|r| (r.queue_time + r.run_time).as_secs_f64() * 1e3)
            .collect();
        let ps = percentiles(&lat, &[0.5, 0.99]);
        t.row(&[
            artifact.into(),
            responses.len().to_string(),
            format!("{:.0}", server.stats.tokens_processed as f64 / wall),
            format!("{:.1}", ps[0]),
            format!("{:.1}", ps[1]),
            server.stats.peak_active_slots.to_string(),
        ]);
    }
    println!("== PJRT continuous-batching server ==");
    t.print();

    // single-stream ASIC-style path for the ternary model
    let sess = Session::open(&engine, &dir, "char_ptb_ter")?;
    let mut cell = PackedLstmCell::from_session(&sess, 3)?;
    let mut h = vec![0.0f32; cell.hidden];
    let mut c = vec![0.0f32; cell.hidden];
    let t0 = Instant::now();
    let n = 50_000;
    for i in 0..n {
        cell.step_token(i % 50, &mut h, &mut c);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("\n== packed popcount engine (single stream, ternary) ==");
    println!("{:.0} steps/s, weight footprint {} B", n as f64 / dt,
             cell.weight_bytes());
    Ok(())
}
