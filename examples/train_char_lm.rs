//! END-TO-END driver (DESIGN.md §5): trains the paper's BN-LSTM with
//! binary and ternary weights plus the full-precision baseline on the
//! synthetic PTB-like corpus, through the complete stack:
//!
//!   rust data pipeline → AOT PJRT train_step → rust optimizer-state
//!   ownership → eval (running BN stats, stochastic weight samples) →
//!   packed-weight export → rust-native popcount-engine generation.
//!
//!   cargo run --release --example train_char_lm [steps]
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::path::PathBuf;
use std::time::Instant;

use rbtw::coordinator::{Split, TrainSpec, Trainer};
use rbtw::quant::PackedLstmCell;
use rbtw::runtime::Engine;
use rbtw::util::table::Table;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(600);
    let dir = PathBuf::from("artifacts");
    let engine = Engine::cpu()?;
    let mut rows = Table::new(&["model", "precision", "steps", "final train",
                                "valid bpc", "test bpc", "time s"]);
    let mut packed_demo: Option<(String, PackedLstmCell)> = None;

    for (artifact, label) in [("char_ptb_fp", "Full-precision LSTM"),
                              ("char_ptb_bin", "BN-LSTM binary (ours)"),
                              ("char_ptb_ter", "BN-LSTM ternary (ours)")] {
        let spec = TrainSpec { steps, lr: 1e-2, eval_every: (steps / 6).max(1),
                               eval_batches: 4, verbose: true,
                               ..TrainSpec::default() };
        let mut trainer = Trainer::new(&engine, &dir, artifact, spec)?;
        let t0 = Instant::now();
        let report = trainer.run()?;
        let secs = t0.elapsed().as_secs_f64();
        let test = trainer.evaluate(Split::Test, 6)?;
        println!("\n{label}: loss curve (every {} steps): {}",
                 (steps / 12).max(1),
                 report.train_loss.render((steps / 12).max(1)));
        rows.row(&[
            label.into(),
            trainer.sess.meta.quantizer().into(),
            steps.to_string(),
            format!("{:.4}", report.train_loss.tail_mean(10).unwrap()),
            format!("{:.3}", report.final_valid),
            format!("{:.3}", test.metric),
            format!("{secs:.0}"),
        ]);
        // keep the ternary model for the deployment demo
        if artifact == "char_ptb_ter" {
            packed_demo = Some((label.to_string(),
                                PackedLstmCell::from_session(&trainer.sess, 7)?));
        }
    }

    println!("\n== end-to-end training summary ==");
    rows.print();

    // deployment path: generate text with the rust-native popcount engine
    let (label, mut cell) = packed_demo.unwrap();
    println!("\n== deployment demo: {label} on the packed popcount engine ==");
    println!("packed weight footprint: {} B", cell.weight_bytes());
    let mut h = vec![0.0f32; cell.hidden];
    let mut c = vec![0.0f32; cell.hidden];
    let t0 = Instant::now();
    let n_tokens = 20_000;
    let mut tok = 0usize;
    let mut checksum = 0.0f32;
    for _ in 0..n_tokens {
        cell.step_token(tok, &mut h, &mut c);
        // greedy-ish next token from the hidden state's strongest unit
        tok = (h.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i).unwrap_or(0)) % 50;
        checksum += h[0];
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("{n_tokens} recurrent steps in {dt:.3}s = {:.0} steps/s \
              (checksum {checksum:.3})", n_tokens as f64 / dt);
    println!("\nall layers composed: data → PJRT train/eval → packed export \
              → native inference ✓");
    Ok(())
}
