//! Front-door wire client vs in-process reference — the digest gate.
//!
//!   # terminal 1: serve the synthetic model over TCP
//!   cargo run --release -- serve synthetic --listen 127.0.0.1:4250 \
//!       --shards 2 --slots 4 < /dev/null
//!
//!   # terminal 2: drive the same greedy load over the socket
//!   cargo run --release --example netclient -- --connect 127.0.0.1:4250
//!
//!   # reference: the identical load served in-process (no sockets)
//!   cargo run --release --example netclient -- --local
//!
//! Both modes build the SAME deterministic greedy load
//! (`LoadSpec::requests`, temperature 0) against the SAME model
//! (`ModelWeights::synthetic_serving`, the shape `rbtw serve synthetic`
//! builds) and print one `greedy:<fnv1a64>` digest over the id-sorted
//! responses — ids, generated tokens, and the raw f64 bits of each
//! prompt log-prob. The wire carries the log-prob as bits
//! (`done ... <logprob_bits>`), so if serving over TCP perturbs a
//! single token or a single mantissa bit anywhere, the two digests
//! split. `ci.sh` runs both and compares.
//!
//! `--drain` additionally asks the server to drain and shut down after
//! the load completes (what ci.sh uses to end the smoke server).
//! `--expect-respawn` asserts via `/metrics` that supervision respawned
//! at least one shard worker before the load finished — the chaos gate
//! combines it with `RBTW_FAULT_PLAN` on the server side to prove a
//! mid-load crash is invisible in the digest.

use rbtw::cluster::run_cluster_load;
use rbtw::config::ServeSpec;
use rbtw::coordinator::LoadSpec;
use rbtw::engine::{BackendSpec, CellArch, ModelWeights, SharedModel};
use rbtw::frontdoor::{FrontDoorClient, WireOutcome};

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn feed(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// One digest shape for both transports: (id, tokens, logprob bits)
/// per response, sorted by id.
fn digest(mut rows: Vec<(u64, Vec<i32>, u64)>) -> u64 {
    rows.sort_by_key(|r| r.0);
    let mut h = FNV_OFFSET;
    for (id, tokens, logprob_bits) in rows {
        feed(&mut h, &id.to_le_bytes());
        for t in tokens {
            feed(&mut h, &t.to_le_bytes());
        }
        feed(&mut h, &logprob_bits.to_le_bytes());
    }
    h
}

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn usize_flag(args: &[String], name: &str, default: usize)
    -> anyhow::Result<usize> {
    match flag(args, name) {
        Some(s) => s.parse().map_err(|_| anyhow::anyhow!(
            "{name} takes a non-negative integer, got '{s}'")),
        None => Ok(default),
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let connect = flag(&args, "--connect");
    let local = args.iter().any(|a| a == "--local");
    anyhow::ensure!(connect.is_some() != local,
                    "pick exactly one mode: --connect HOST:PORT or --local");
    let n_requests = usize_flag(&args, "--requests", 24)?.max(1);
    let prompt_len = usize_flag(&args, "--prompt-len", 8)?.max(1);
    let gen_len = usize_flag(&args, "--gen-len", 12)?.max(1);
    let window = usize_flag(&args, "--window", 32)?.max(1);
    let shards = usize_flag(&args, "--shards", 2)?.max(1);
    let slots = usize_flag(&args, "--slots", 4)?.max(1);
    let arch = match flag(&args, "--arch") {
        Some(a) => CellArch::parse(&a)?,
        None => CellArch::Lstm,
    };
    let layers = usize_flag(&args, "--layers", 1)?
        .clamp(1, BackendSpec::MAX_LAYERS);
    let drain = args.iter().any(|a| a == "--drain");
    let expect_respawn = args.iter().any(|a| a == "--expect-respawn");

    // identical greedy load for both transports: temperature 0 makes
    // every response a pure function of model + prompt
    let weights = ModelWeights::synthetic_serving(arch, layers);
    let load = LoadSpec {
        n_requests,
        prompt_len,
        gen_len,
        temperature: 0.0,
        seed: 0xD007,
    };
    let requests = load.requests(weights.vocab);

    let rows: Vec<(u64, Vec<i32>, u64)> = if let Some(addr) = connect {
        let mut client = FrontDoorClient::connect(&addr)?;
        let proto = client.hello()?;
        println!("hello: protocol v{proto}");
        client.ping()?;
        // session wire smoke (quiet connection, before the greedy
        // stream): prefill + suspend under a session id, then resume
        // with a continuation on the same id. Ids live far above the
        // load's so they never enter the greedy digest rows.
        {
            let vocab = weights.vocab as i32;
            let prefix: Vec<i32> = (0..6).map(|i| (i * 5 + 2) % vocab)
                .collect();
            let o = client.session(7, 900_001, 0.0, prefix)?;
            let done = o.done().ok_or_else(|| anyhow::anyhow!(
                "session suspend refused: {o:?}"))?;
            anyhow::ensure!(done.tokens.is_empty(),
                            "session suspend must not generate");
            let cont: Vec<i32> = vec![1 % vocab, 3 % vocab];
            let o = client.resume(7, 900_002, 4, 0.0, cont)?;
            let done = o.done().ok_or_else(|| anyhow::anyhow!(
                "session resume refused: {o:?}"))?;
            anyhow::ensure!(done.tokens.len() == 4,
                            "resume generated {} tokens, wanted 4",
                            done.tokens.len());
            println!("session-roundtrip: ok (sid 7, {} tokens resumed \
                      on shard {})", done.tokens.len(), done.shard);
        }
        let t0 = std::time::Instant::now();
        let outcomes = client.run_greedy(&requests, window)?;
        let wall = t0.elapsed().as_secs_f64();
        let mut rows = Vec::with_capacity(outcomes.len());
        for o in outcomes {
            match o {
                WireOutcome::Done(r) => {
                    rows.push((r.id, r.tokens, r.logprob_bits));
                }
                WireOutcome::Busy(id) => anyhow::bail!(
                    "request {id} refused: server overloaded (busy)"),
                WireOutcome::Closing(id) => anyhow::bail!(
                    "request {id} refused: server draining"),
                WireOutcome::Expired(id) => anyhow::bail!(
                    "request {id} refused: deadline expired"),
                WireOutcome::Failed { id, msg } => anyhow::bail!(
                    "request {id} failed: {msg}"),
            }
        }
        let tokens: usize = rows.iter().map(|r| r.1.len()).sum();
        println!("wire: {} responses over {addr} in {wall:.2}s \
                  ({:.0} tok/s end-to-end)",
                 rows.len(), tokens as f64 / wall);
        if expect_respawn {
            // scrape BEFORE the drain tears the cluster down
            let metrics = client.metrics()?;
            let respawns: u64 = metrics
                .lines()
                .find_map(|l| l.strip_prefix("rbtw_cluster_respawns "))
                .ok_or_else(|| anyhow::anyhow!(
                    "rbtw_cluster_respawns missing from /metrics"))?
                .trim()
                .parse()?;
            anyhow::ensure!(respawns > 0,
                            "--expect-respawn: no shard worker respawned \
                             (is RBTW_FAULT_PLAN armed on the server?)");
            println!("respawns: {respawns}");
        }
        if drain {
            let ack = client.drain_server()?;
            println!("server ack: {ack}");
        }
        rows
    } else {
        let mut sspec = ServeSpec::default();
        sspec.arch = arch;
        sspec.layers = layers;
        sspec.shards = shards;
        sspec.slots = slots;
        let shared =
            SharedModel::prepare(&weights, sspec.backend, sspec.sample_seed)?;
        let report = run_cluster_load(&shared, &sspec.backend_spec(),
                                      sspec.policy, sspec.queue_cap, &load)?;
        println!("local: {} responses in-process ({:.0} tok/s)",
                 report.responses.len(), report.tokens_per_sec());
        report.responses.into_iter()
            .map(|cr| {
                let r = cr.into_done().expect("local run serves everything");
                (r.id, r.generated, r.prompt_logprob.to_bits())
            })
            .collect()
    };

    anyhow::ensure!(rows.len() == n_requests,
                    "expected {n_requests} responses, got {}", rows.len());
    println!("greedy:{:016x}", digest(rows));
    Ok(())
}
