"""AOT boundary tests: the registry is well-formed, lowering produces
consistent meta/HLO/init triples, and pack_ternary_ref matches the rust
packing convention."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import quantizers as Q
from compile.kernels.ref import pack_ternary_ref


class TestRegistry:
    def test_table_coverage(self):
        """Every table of the paper has registry entries."""
        names = set(aot.REGISTRY)
        # Table 1: 12 methods x 3 corpora
        for c in ["ptb", "wp", "lk"]:
            for m in aot._CHAR_METHODS:
                assert f"char_{c}_{m}" in names
        # Table 2
        assert {"char_text8_fp", "char_text8_bin", "char_text8_ter",
                "char_text8_bc"} <= names
        # Table 3
        assert {"word_small_fp", "word_small_alt4", "word_large_ter"} <= names
        # Table 4 / 5 / 6
        assert {"mnist_fp", "mnist_alt2", "qa_ter", "gru_ptb_ter"} <= names
        # Fig 3 batch sweep
        assert "char_ptb_ter_b8" in names

    def test_paper_rows_carry_published_values(self):
        e = aot.REGISTRY["char_ptb_ter"]
        assert e.paper["value"] == 1.39
        assert e.paper["hidden"] == 1000
        e = aot.REGISTRY["word_small_alt2"]
        assert e.paper["value"] == 103.1
        assert e.paper["ops_multiplier"] == 2

    def test_ours_use_bn_baselines_do_not(self):
        assert aot.REGISTRY["char_ptb_ter"].model.arch == "bnlstm"
        assert aot.REGISTRY["char_ptb_bc"].model.arch == "lstm"
        assert aot.REGISTRY["char_ptb_fp"].model.arch == "lstm"

    def test_bits_consistent_with_quantizers(self):
        for name, e in aot.REGISTRY.items():
            if "bits" in e.paper:
                assert e.paper["bits"] == Q.bits(e.model.quantizer), name


class TestLowering:
    @pytest.fixture(scope="class")
    def lowered(self, tmp_path_factory):
        out = str(tmp_path_factory.mktemp("aot"))
        # smallest bundle for speed: shrink a charlm config
        import dataclasses
        e = aot.REGISTRY["char_ptb_ter"]
        small = dataclasses.replace(
            e,
            name="tiny_test",
            model=dataclasses.replace(e.model, hidden=16),
            train=dataclasses.replace(e.train, seq_len=8, batch=4),
            entries=("train", "eval"),
            eval_variants=(),
            infer_variants=(("b2", 2),),
        )
        aot.lower_experiment(small, out, verbose=False)
        return out

    def test_files_exist(self, lowered):
        for f in ["tiny_test.meta.json", "tiny_test.init.bin",
                  "tiny_test_train.hlo.txt", "tiny_test_eval.hlo.txt",
                  "tiny_test_infer_b2.hlo.txt"]:
            assert os.path.exists(os.path.join(lowered, f)), f

    def test_meta_io_consistency(self, lowered):
        meta = json.load(open(os.path.join(lowered, "tiny_test.meta.json")))
        train = meta["entrypoints"]["train"]
        groups = [i["group"] for i in train["inputs"]]
        # params/state/opt arrive before data/scalars, in sorted order
        p_names = [i["name"] for i in train["inputs"] if i["group"] == "params"]
        assert p_names == sorted(p_names)
        # outputs = params + state + opt + loss
        n_pso = sum(1 for g in groups if g in ("params", "state", "opt"))
        assert len(train["outputs"]) == n_pso + 1
        # init.bin covers each params/state/opt leaf exactly once
        seg = [(s["group"], s["name"]) for s in meta["init"]["segments"]]
        assert len(seg) == len(set(seg)) == n_pso

    def test_init_bin_size(self, lowered):
        meta = json.load(open(os.path.join(lowered, "tiny_test.meta.json")))
        size = os.path.getsize(os.path.join(lowered, "tiny_test.init.bin"))
        assert size == meta["init"]["total_bytes"]
        total = sum(s["nbytes"] for s in meta["init"]["segments"])
        assert total == size

    def test_hlo_entry_arity(self, lowered):
        meta = json.load(open(os.path.join(lowered, "tiny_test.meta.json")))
        hlo = open(os.path.join(lowered, "tiny_test_eval.hlo.txt")).read()
        n_inputs = len(meta["entrypoints"]["eval"]["inputs"])
        header = hlo.split("\n", 1)[0]
        # entry_computation_layout lists every parameter
        assert header.count("f32[") + header.count("s32[") >= n_inputs

    def test_footprint_counts(self, lowered):
        meta = json.load(open(os.path.join(lowered, "tiny_test.meta.json")))
        fp = meta["footprint"]
        # 4 gates x 16 hidden x (50 + 16) inputs
        assert fp["recurrent_params"] == 4 * 16 * (50 + 16)
        assert fp["bytes_quant"] * 4 == fp["bytes_fp32"] / 4  # 2-bit ternary


class TestPackingOracle:
    def test_pack_ternary_ref_shape(self):
        w = jnp.asarray(np.random.RandomState(0).choice(
            [-1.0, 0.0, 1.0], size=(70, 5)).astype(np.float32))
        sign, mask = pack_ternary_ref(w)
        assert sign.shape == (9, 5)  # ceil(70/8)
        assert mask.shape == (9, 5)

    def test_pack_ternary_ref_bits(self):
        w = jnp.asarray([[1.0], [0.0], [-1.0], [1.0]])
        sign, mask = pack_ternary_ref(w)
        # rows 0..3 -> bits 0..3: mask 0b1101, sign 0b1001
        assert int(mask[0, 0]) == 0b1101
        assert int(sign[0, 0]) == 0b1001
