"""Quantizer laws: Eq. 4-6 probabilities, codomains, STE gradients, and
the baseline quantizers' defining properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantizers as Q


KEY = jax.random.PRNGKey(0)


def in_set(arr, values, tol=1e-6):
    arr = np.asarray(arr)
    return all(min(abs(arr.flat[i] - v) for v in values) < tol
               for i in range(arr.size))


class TestOursBinary:
    def test_codomain(self):
        alpha = 0.5
        w = jax.random.normal(KEY, (64, 64)) * 0.2
        wq = Q.get("bin", alpha)(w, KEY)
        assert in_set(wq, [alpha, -alpha])

    def test_probability_law(self):
        """Eq. 4: P(+1) = (wn+1)/2 — check empirically at wn=0.5."""
        alpha = 1.0
        w = jnp.full((200, 200), 0.5)
        keys = jax.random.split(KEY, 8)
        rates = [float(jnp.mean(Q.get("bin", alpha)(w, k) > 0)) for k in keys]
        assert abs(np.mean(rates) - 0.75) < 0.01

    def test_expectation_unbiased(self):
        """E[wq] == w (clipped): stochastic rounding is unbiased."""
        alpha = 1.0
        w = jnp.linspace(-0.9, 0.9, 19)
        keys = jax.random.split(KEY, 2000)
        acc = sum(Q.get("bin", alpha)(w, k) for k in keys) / 2000.0
        np.testing.assert_allclose(np.asarray(acc), np.asarray(w), atol=0.05)

    def test_saturated_deterministic(self):
        alpha = 0.3
        w = jnp.full((16,), 10.0)  # wn clips to +1
        wq = Q.get("bin", alpha)(w, KEY)
        assert bool(jnp.all(wq == alpha))

    def test_ste_gradient_identity(self):
        alpha = 0.25
        w = jax.random.normal(KEY, (8, 8)) * 0.1
        g = jax.grad(lambda p: Q.get("bin", alpha)(p, KEY).sum())(w)
        np.testing.assert_allclose(np.asarray(g), np.ones((8, 8)), atol=1e-6)


class TestOursTernary:
    def test_codomain(self):
        alpha = 0.5
        w = jax.random.normal(KEY, (64, 64)) * 0.2
        wq = Q.get("ter", alpha)(w, KEY)
        assert in_set(wq, [alpha, 0.0, -alpha])

    def test_zero_weight_stays_zero(self):
        wq = Q.get("ter", 1.0)(jnp.zeros((32, 32)), KEY)
        assert bool(jnp.all(wq == 0.0))

    def test_probability_law(self):
        """Eq. 5: P(nonzero) = |wn|."""
        alpha = 1.0
        w = jnp.full((300, 300), -0.3)
        keys = jax.random.split(KEY, 8)
        rates = [float(jnp.mean(Q.get("ter", alpha)(w, k) != 0)) for k in keys]
        assert abs(np.mean(rates) - 0.3) < 0.01
        # and the nonzeros carry sign(w)
        wq = Q.get("ter", alpha)(w, KEY)
        nz = np.asarray(wq)[np.asarray(wq) != 0]
        assert (nz < 0).all()

    def test_expectation_unbiased(self):
        alpha = 1.0
        w = jnp.linspace(-0.8, 0.8, 17)
        keys = jax.random.split(KEY, 2000)
        acc = sum(Q.get("ter", alpha)(w, k) for k in keys) / 2000.0
        np.testing.assert_allclose(np.asarray(acc), np.asarray(w), atol=0.05)

    def test_ste_gradient_identity(self):
        g = jax.grad(lambda p: Q.get("ter", 0.5)(p, KEY).sum())(
            jax.random.normal(KEY, (6, 6)) * 0.1)
        np.testing.assert_allclose(np.asarray(g), np.ones((6, 6)), atol=1e-6)


class TestBaselines:
    def test_binaryconnect_is_sign(self):
        alpha = 0.2
        w = jax.random.normal(KEY, (32, 32))
        wq = Q.get("bc", alpha)(w, KEY)
        np.testing.assert_allclose(np.asarray(wq),
                                   alpha * np.where(np.asarray(w) >= 0, 1, -1))

    def test_lab_scale_is_column_mean_abs(self):
        w = jax.random.normal(KEY, (64, 8))
        wq = Q.get("lab", 1.0)(w, KEY)
        want = np.mean(np.abs(np.asarray(w)), axis=0, keepdims=True)
        np.testing.assert_allclose(np.abs(np.asarray(wq)),
                                   np.broadcast_to(want, (64, 8)), rtol=1e-5)

    def test_twn_threshold(self):
        w = jax.random.normal(KEY, (128, 128))
        wq = np.asarray(Q.get("twn", 1.0)(w, KEY))
        delta = 0.7 * np.mean(np.abs(np.asarray(w)))
        # below-threshold entries are zero
        below = np.abs(np.asarray(w)) <= delta
        assert (wq[below] == 0).all()
        # above-threshold entries share one scale
        nz = np.abs(wq[~below])
        assert nz.size > 0 and np.allclose(nz, nz[0], rtol=1e-5)

    @pytest.mark.parametrize("k", [2, 3, 4])
    def test_dorefa_level_count(self, k):
        w = jax.random.normal(KEY, (64, 64))
        wq = np.asarray(Q.get(f"dorefa{k}", 1.0)(w, KEY))
        levels = np.unique(np.round(wq, 5))
        assert len(levels) <= 2 ** k
        assert wq.min() >= -1.0 - 1e-5 and wq.max() <= 1.0 + 1e-5

    @pytest.mark.parametrize("k", [2, 3, 4])
    def test_laq_grid(self, k):
        w = jax.random.normal(KEY, (64, 64))
        wq = np.asarray(Q.get(f"laq{k}", 1.0)(w, KEY))
        m = 2 ** (k - 1) - 1
        levels = np.unique(np.round(wq / (np.abs(wq)[np.abs(wq) > 0].min()
                                          if (np.abs(wq) > 0).any() else 1.0)))
        assert len(np.unique(np.round(wq, 6))) <= 2 * m + 1

    @pytest.mark.parametrize("k", [1, 2, 3, 4])
    def test_alternating_error_decreases_with_k(self, k):
        w = jax.random.normal(KEY, (64, 64))
        wq = Q.get(f"alt{k}", 1.0)(w, KEY)
        err = float(jnp.mean((w - wq) ** 2))
        if k > 1:
            prev = Q.get(f"alt{k-1}", 1.0)(w, KEY)
            err_prev = float(jnp.mean((w - prev) ** 2))
            assert err < err_prev, f"k={k}: {err} !< {err_prev}"

    def test_ttq_asymmetric_scales(self):
        w = jax.random.normal(KEY, (64, 64))
        wq = np.asarray(Q.ttq_apply(w, KEY, jnp.asarray(0.7), jnp.asarray(0.3)))
        pos = np.unique(wq[wq > 0])
        neg = np.unique(wq[wq < 0])
        np.testing.assert_allclose(pos, [0.7], rtol=1e-6)
        np.testing.assert_allclose(neg, [-0.3], rtol=1e-6)

    def test_fp_identity(self):
        w = jax.random.normal(KEY, (16, 16))
        np.testing.assert_array_equal(np.asarray(Q.get("fp", 1.0)(w, KEY)),
                                      np.asarray(w))


class TestRegistry:
    def test_bits_table(self):
        assert Q.bits("bin") == 1.0
        assert Q.bits("ter") == 2.0
        assert Q.bits("fp") == 32.0
        assert Q.bits("alt4") == 4.0
        assert Q.bits("ttq") == 2.0

    def test_ops_multiplier(self):
        assert Q.OPS_MULTIPLIER["alt2"] == 2
        assert "bin" not in Q.OPS_MULTIPLIER

    def test_glorot_alpha(self):
        assert abs(Q.glorot_alpha(96, 384) - (6.0 / 480) ** 0.5) < 1e-9

    @settings(max_examples=20, deadline=None)
    @given(name=st.sampled_from(["bin", "ter", "bc", "lab", "twn",
                                 "dorefa3", "laq2", "alt2"]),
           seed=st.integers(0, 2 ** 30))
    def test_all_quantizers_finite(self, name, seed):
        w = jax.random.normal(jax.random.PRNGKey(seed), (24, 24)) * 0.5
        wq = Q.get(name, 0.5)(w, jax.random.PRNGKey(seed + 1))
        assert bool(jnp.isfinite(wq).all())
