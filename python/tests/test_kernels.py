"""L1 kernel correctness: Pallas vs the pure-jnp oracles in ref.py.

This is the core numerics signal of the repo — the same kernels lower
into the serving artifacts the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.quant_matmul import (BlockPlan, choose_block_plan,
                                          qmatmul, qmatmul_bn, qmatmul_ste)
from compile.kernels.bnlstm_cell import bnlstm_cell, fold_bn
from compile.kernels import ref


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     jnp.float32)


def tern(key, shape):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    sign = jnp.sign(jax.random.normal(k1, shape))
    mask = (jax.random.uniform(k2, shape) < 0.7).astype(jnp.float32)
    return sign * mask


class TestQMatmul:
    def test_matches_ref_basic(self):
        x = rand(0, (48, 96))
        w = tern(1, (96, 384))
        np.testing.assert_allclose(np.asarray(qmatmul(x, w)),
                                   np.asarray(ref.qmatmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 70), k=st.integers(1, 130), n=st.integers(1, 150))
    def test_matches_ref_shapes(self, m, k, n):
        x = rand(m * 1000 + k, (m, k))
        w = tern(n, (k, n))
        np.testing.assert_allclose(np.asarray(qmatmul(x, w)),
                                   np.asarray(ref.qmatmul_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    @settings(max_examples=8, deadline=None)
    @given(bm=st.integers(8, 64), bk=st.integers(8, 96), bn=st.integers(8, 128))
    def test_block_plan_invariance(self, bm, bk, bn):
        """Any tile shape must give the same numbers (grid correctness)."""
        x = rand(7, (40, 96))
        w = tern(8, (96, 120))
        out = qmatmul(x, w, plan=BlockPlan(bm, bk, bn))
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.qmatmul_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    def test_binary_weights(self):
        x = rand(2, (16, 32))
        w = jnp.sign(rand(3, (32, 64)) + 1e-9)
        np.testing.assert_allclose(np.asarray(qmatmul(x, w)),
                                   np.asarray(ref.qmatmul_ref(x, w)),
                                   rtol=1e-5, atol=1e-5)

    def test_vjp_matches_dense_grad(self):
        x = rand(4, (8, 16))
        w = tern(5, (16, 24))
        g1 = jax.grad(lambda a: qmatmul_ste(a, w).sum())(x)
        g2 = jax.grad(lambda a: ref.qmatmul_ref(a, w).sum())(x)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-5, atol=1e-5)
        gw1 = jax.grad(lambda b: qmatmul_ste(x, b).sum())(w)
        gw2 = jax.grad(lambda b: ref.qmatmul_ref(x, b).sum())(w)
        np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2),
                                   rtol=1e-5, atol=1e-5)


class TestQMatmulBN:
    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(2, 48), k=st.integers(2, 96), n=st.integers(2, 128))
    def test_matches_ref(self, m, k, n):
        x = rand(m, (m, k))
        w = tern(k, (k, n))
        mean = rand(n + 1, (n,), 0.2)
        var = jnp.abs(rand(n + 2, (n,))) + 0.3
        phi = jnp.abs(rand(n + 3, (n,), 0.2)) + 0.05
        gamma = rand(n + 4, (n,), 0.1)
        got = qmatmul_bn(x, w, mean, var, phi, gamma)
        want = ref.qmatmul_bn_ref(x, w, mean, var, phi, gamma)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-4, atol=5e-4)

    def test_identity_bn_is_plain_matmul(self):
        x = rand(1, (8, 16))
        w = tern(2, (16, 32))
        got = qmatmul_bn(x, w, jnp.zeros(32), jnp.ones(32) - 1e-5,
                         jnp.ones(32), jnp.zeros(32))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.qmatmul_ref(x, w)),
                                   rtol=1e-4, atol=1e-4)


class TestFusedCell:
    @settings(max_examples=10, deadline=None)
    @given(batch=st.integers(1, 24), dx=st.integers(1, 60),
           hid=st.integers(1, 48))
    def test_matches_composed_ref(self, batch, dx, hid):
        x = rand(batch, (batch, dx))
        h = rand(batch + 1, (batch, hid), 0.1)
        c = rand(batch + 2, (batch, hid), 0.1)
        wx = tern(dx, (dx, 4 * hid))
        wh = tern(hid + 7, (hid, 4 * hid))
        b = rand(batch + 3, (4 * hid,), 0.1)
        mean = rand(batch + 4, (4 * hid,), 0.1)
        var = jnp.abs(rand(batch + 5, (4 * hid,))) + 0.4
        phi = jnp.full((4 * hid,), 0.1)
        gamma = jnp.zeros(4 * hid)
        sx, tx = fold_bn(mean, var, phi, gamma)
        sh, th = fold_bn(mean * 0.3, var * 1.2, phi, gamma)
        hn, cn = bnlstm_cell(x, h, c, wx, wh, sx, tx, sh, th, b)
        xw = ref.bn_apply_ref(ref.qmatmul_ref(x, wx), mean, var, phi, gamma)
        hw = ref.bn_apply_ref(ref.qmatmul_ref(h, wh), mean * 0.3, var * 1.2,
                              phi, gamma)
        hr, cr = ref.lstm_cell_ref(xw, hw, b, c)
        np.testing.assert_allclose(np.asarray(hn), np.asarray(hr),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(cn), np.asarray(cr),
                                   rtol=1e-3, atol=1e-3)

    def test_state_bounds(self):
        """h = o * tanh(c) must stay in (-1, 1)."""
        x = rand(0, (8, 20), 3.0)
        h = rand(1, (8, 16), 3.0)
        c = rand(2, (8, 16), 3.0)
        wx = tern(3, (20, 64))
        wh = tern(4, (16, 64))
        ones, zeros = jnp.ones(64), jnp.zeros(64)
        hn, _ = bnlstm_cell(x, h, c, wx, wh, ones, zeros, ones, zeros, zeros)
        assert bool(jnp.all(jnp.abs(hn) <= 1.0))


class TestBlockPlanModel:
    def test_vmem_within_budget(self):
        plan = choose_block_plan(256, 2000, 8000)
        assert plan.vmem_bytes() <= 16 * 2 ** 20

    def test_mxu_utilization_bounds(self):
        plan = BlockPlan(128, 128, 128)
        u = plan.mxu_utilization(1024, 1024, 1024)
        assert 0.0 < u <= 1.0

    def test_small_problem_clamps(self):
        plan = choose_block_plan(4, 10, 12)
        assert plan.bm >= 1 and plan.bk >= 1 and plan.bn >= 1
