"""L2 model shape/finite-ness/behavioral tests: BN-LSTM vs vanilla, GRU,
attentive reader, BN statistics flow, and the train/eval step builders."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile import model as M
from compile import train as T


KEY = jax.random.PRNGKey(0)


def make(arch="bnlstm", quant="ter", **kw):
    cfg = M.ModelConfig(arch=arch, quantizer=quant, vocab=30, hidden=24, **kw)
    params, state = M.init_params(cfg, KEY)
    return cfg, params, state


def tokens(t=12, b=4, vocab=30, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (t, b), 0, vocab)


class TestBatchNorm:
    def test_train_normalizes(self):
        x = jax.random.normal(KEY, (64, 8)) * 3.0 + 2.0
        y, mean, var = L.bn_train(x, jnp.ones(8), 0.0)
        np.testing.assert_allclose(np.asarray(jnp.mean(y, axis=0)),
                                   np.zeros(8), atol=1e-5)
        np.testing.assert_allclose(np.asarray(jnp.std(y, axis=0)),
                                   np.ones(8), atol=1e-2)
        np.testing.assert_allclose(np.asarray(mean),
                                   np.asarray(jnp.mean(x, axis=0)), rtol=1e-5)

    def test_infer_uses_given_stats(self):
        x = jnp.ones((4, 3))
        y = L.bn_infer(x, jnp.ones(3), 0.0, jnp.ones(3), jnp.ones(3))
        np.testing.assert_allclose(np.asarray(y), np.zeros((4, 3)), atol=1e-3)

    def test_ema(self):
        r = L.ema_update(jnp.ones(3), jnp.zeros(3), momentum=0.9)
        np.testing.assert_allclose(np.asarray(r), 0.9 * np.ones(3), rtol=1e-6)


class TestInitParams:
    def test_bnlstm_has_bn_params(self):
        cfg, params, state = make()
        assert "l0/phi_x" in params and "l0/phi_h" in params
        assert "l0/rm_x" in state and "l0/rv_h" in state

    def test_vanilla_has_no_bn(self):
        cfg, params, state = make(arch="lstm", quant="bc")
        assert "l0/phi_x" not in params
        assert not state

    def test_forget_gate_bias_one(self):
        cfg, params, _ = make()
        b = np.asarray(params["l0/b"])
        h = cfg.hidden
        np.testing.assert_array_equal(b[h:2 * h], np.ones(h))
        np.testing.assert_array_equal(b[:h], np.zeros(h))

    def test_gru_param_shapes(self):
        cfg, params, _ = make(arch="bngru")
        assert params["l0/wx"].shape == (30, 3 * 24)
        assert params["l0/wh"].shape == (24, 3 * 24)

    def test_ttq_extra_scales(self):
        cfg, params, _ = make(arch="lstm", quant="ttq")
        assert "l0/ttq_wp_x" in params and "l0/ttq_wn_h" in params

    def test_multilayer(self):
        cfg, params, _ = make(num_layers=2)
        assert params["l1/wx"].shape == (24, 96)


class TestForward:
    @pytest.mark.parametrize("arch,quant", [
        ("bnlstm", "bin"), ("bnlstm", "ter"), ("lstm", "fp"),
        ("lstm", "bc"), ("bngru", "ter"), ("gru", "fp"), ("lstm", "ttq"),
    ])
    def test_shapes_and_finite(self, arch, quant):
        cfg, params, state = make(arch=arch, quant=quant)
        hs, finals, upd, _ = M.rnn_forward(cfg, params, state, tokens(),
                                           KEY, True)
        assert hs.shape == (12, 4, 24)
        assert bool(jnp.isfinite(hs).all())
        if cfg.use_bn:
            assert upd, "BN must emit running-stat updates in train mode"
        else:
            assert not upd

    def test_eval_mode_deterministic_given_seed(self):
        cfg, params, state = make()
        a, _, _, _ = M.rnn_forward(cfg, params, state, tokens(), KEY, False)
        b, _, _, _ = M.rnn_forward(cfg, params, state, tokens(), KEY, False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_quantization_seed_changes_output(self):
        cfg, params, state = make()
        a, _, _, _ = M.rnn_forward(cfg, params, state, tokens(),
                                   jax.random.PRNGKey(1), False)
        b, _, _, _ = M.rnn_forward(cfg, params, state, tokens(),
                                   jax.random.PRNGKey(2), False)
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_fp_ignores_seed(self):
        cfg, params, state = make(arch="lstm", quant="fp")
        a, _, _, _ = M.rnn_forward(cfg, params, state, tokens(),
                                   jax.random.PRNGKey(1), False)
        b, _, _, _ = M.rnn_forward(cfg, params, state, tokens(),
                                   jax.random.PRNGKey(2), False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_quantized_weights_in_codomain(self):
        cfg, params, _ = make(quant="ter")
        wq = M.quantize_weights(cfg, params, KEY)
        import math
        alpha = math.sqrt(6.0 / (30 + 96))
        vals = np.unique(np.asarray(wq["l0/wx"]))
        for v in vals:
            assert min(abs(v - t) for t in (-alpha, 0.0, alpha)) < 1e-5, v

    def test_gate_trace_ranges(self):
        cfg, params, state = make()
        _, _, _, tr = M.rnn_forward(cfg, params, state, tokens(), KEY, True,
                                    collect_gates=True)
        for g in ["i", "f", "o"]:
            arr = np.asarray(tr[g])
            assert arr.min() >= 0.0 and arr.max() <= 1.0
        assert np.abs(np.asarray(tr["g"])).max() <= 1.0


class TestAttentiveReader:
    def test_forward_and_loss(self):
        cfg = M.ModelConfig(arch="bnlstm", quantizer="ter", vocab=120,
                            emb_dim=16, hidden=12, head="attreader",
                            num_classes=30)
        params, state = M.init_attreader(cfg, KEY)
        doc = jax.random.randint(KEY, (20, 4), 0, 120)
        query = jax.random.randint(jax.random.PRNGKey(1), (5, 4), 0, 120)
        logits, upd = M.attreader_forward(cfg, params, state, doc, query,
                                          KEY, True)
        assert logits.shape == (4, 30)
        assert bool(jnp.isfinite(logits).all())
        # updates must cover all four directional LSTMs
        prefixes = {k[:k.find("l0/")] for k in upd}
        assert prefixes == {"", "bwd/", "query/", "query/bwd/"}


class TestTrainSteps:
    def test_train_step_improves_on_fixed_batch(self):
        cfg, params, state = make(quant="ter")
        tc = T.TrainConfig(optimizer="adam", seq_len=12, batch=4)
        step = T.build_train_step(cfg, tc)
        opt = T.init_opt(tc, params)
        x = tokens()
        y = x  # learnable identity task
        losses = []
        for i in range(25):
            params, state, opt, loss = step(params, state, opt, x, y,
                                            jnp.asarray(i), jnp.asarray(5e-3))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.2, losses[::6]

    def test_weight_clip_keeps_probabilities_valid(self):
        cfg, params, state = make(quant="bin")
        tc = T.TrainConfig(optimizer="adam", seq_len=12, batch=4)
        step = T.build_train_step(cfg, tc)
        opt = T.init_opt(tc, params)
        x = tokens()
        for i in range(5):
            params, state, opt, _ = step(params, state, opt, x, x,
                                         jnp.asarray(i), jnp.asarray(0.1))
        import math
        alpha = math.sqrt(6.0 / (30 + 96))
        assert float(jnp.abs(params["l0/wx"]).max()) <= alpha + 1e-6

    def test_eval_step_scalar(self):
        cfg, params, state = make()
        step = T.build_eval_step(cfg)
        loss = step(params, state, tokens(), tokens(seed=3), jnp.asarray(0))
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss))

    def test_sgd_with_clip(self):
        cfg, params, state = make(arch="lstm", quant="fp")
        tc = T.TrainConfig(optimizer="sgd", grad_clip=0.25, seq_len=12,
                           batch=4)
        step = T.build_train_step(cfg, tc)
        opt = T.init_opt(tc, params)
        _, _, opt2, loss = step(params, state, opt, tokens(), tokens(seed=2),
                                jnp.asarray(0), jnp.asarray(1.0))
        assert bool(jnp.isfinite(loss))
        assert float(opt2["t"]) == 1.0

    def test_classifier_step(self):
        cfg = M.ModelConfig(arch="bnlstm", quantizer="bin", vocab=0,
                            input_dim=2, hidden=16, head="classifier",
                            num_classes=5)
        params, state = M.init_params(cfg, KEY)
        tc = T.TrainConfig(optimizer="adam", seq_len=20, batch=6)
        step = T.build_train_step(cfg, tc)
        opt = T.init_opt(tc, params)
        x = jax.random.normal(KEY, (20, 6, 2))
        y = jax.random.randint(KEY, (6,), 0, 5)
        _, _, _, loss = step(params, state, opt, x, y, jnp.asarray(0),
                             jnp.asarray(1e-3))
        assert bool(jnp.isfinite(loss))

    def test_gate_stats_step_outputs(self):
        cfg, params, state = make(arch="lstm", quant="bc")
        step = T.build_gate_stats_step(cfg)
        out = step(params, state, tokens(), jnp.asarray(0))
        assert len(out) == 6
        assert out[0].shape == (12, 4, 24)
