"""Shared L2 building blocks: batch normalization (Eq. 3), initializers,
embeddings, and the softmax cross-entropy head.

Parameter convention: a flat ``dict[str, jnp.ndarray]`` for trainables and
a separate flat dict for non-trainable state (BN running statistics). The
AOT boundary flattens both with sorted keys; rust binds by name via
meta.json.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def glorot_uniform(key, shape):
    """Glorot & Bengio (2010) uniform init — also defines the paper's
    fixed quantization scale alpha (the uniform bound)."""
    fan_in, fan_out = shape[0], shape[-1]
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def orthogonal(key, shape, gain: float = 1.0):
    """Orthogonal init for recurrent matrices (used by the FP baselines)."""
    n = max(shape)
    a = jax.random.normal(key, (n, n), jnp.float32)
    q, _ = jnp.linalg.qr(a)
    return gain * q[: shape[0], : shape[1]]


# ---------------------------------------------------------------------------
# batch normalization (Eq. 3)
# ---------------------------------------------------------------------------

def bn_train(x, phi, gamma, eps: float = BN_EPS):
    """Training-mode BN over the batch axis (axis 0).

    x: (B, N); phi/gamma: (N,). Returns (y, batch_mean, batch_var). The
    statistics are returned so the caller can fold them into the EMA
    running state (Alg. 1 forward pass).
    """
    mean = jnp.mean(x, axis=0)
    var = jnp.var(x, axis=0)
    y = gamma + phi * (x - mean) / jnp.sqrt(var + eps)
    return y, mean, var


def bn_infer(x, phi, gamma, mean, var, eps: float = BN_EPS):
    """Inference-mode BN with running statistics."""
    return gamma + phi * (x - mean) / jnp.sqrt(var + eps)


def ema_update(running, batch, momentum: float = BN_MOMENTUM):
    """Exponential moving average for the running statistics."""
    return momentum * running + (1.0 - momentum) * batch


# ---------------------------------------------------------------------------
# heads
# ---------------------------------------------------------------------------

def dense(params, prefix, x):
    """y = x @ W + b."""
    return x @ params[f"{prefix}/w"] + params[f"{prefix}/b"]


def embedding(params, prefix, tokens):
    """Row lookup; tokens int32 of any shape -> (+emb_dim,)."""
    return params[f"{prefix}/emb"][tokens]


def softmax_xent(logits, targets):
    """Mean cross-entropy in nats.

    logits: (..., V); targets: int32 (...). BPC = loss / ln 2,
    perplexity = exp(loss) — computed on the rust side from this scalar.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def accuracy(logits, targets):
    """Mean top-1 accuracy."""
    return jnp.mean((jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))


def dropout(key, x, rate: float):
    """Inverted dropout; identity when rate == 0."""
    if rate <= 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)
