"""Weight quantizers: the paper's stochastic binary/ternary scheme (Eq.
4–6) and every baseline it is compared against in Tables 1–6.

All quantizers share the straight-through-estimator contract of Eq. 1:
the forward pass emits quantized weights, the backward pass is identity
w.r.t. the full-precision shadow weights. ``ste`` implements that contract
once; each quantizer body is a plain (non-differentiable-ok) function.

Quantizers that need randomness take a PRNG key; deterministic ones ignore
it. All return weights in the *scaled* domain (already multiplied by their
scale), so the model code can use them verbatim in the matmul.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


def ste(fn: Callable) -> Callable:
    """Wrap ``fn(w, key) -> wq`` with an identity VJP w.r.t. ``w`` (Eq. 1).

    The key (and any other operands) get zero cotangents.
    """
    @jax.custom_vjp
    def wrapped(w, key):
        return fn(w, key)

    def fwd(w, key):
        return fn(w, key), None

    def bwd(_, g):
        return (g, None)

    wrapped.defvjp(fwd, bwd)
    return wrapped


# ---------------------------------------------------------------------------
# the paper's quantizers (Eq. 4-6)
# ---------------------------------------------------------------------------

def _ours_binary_raw(w: jnp.ndarray, key, *, alpha: float) -> jnp.ndarray:
    """Eq. 4 + 6: stochastic binarization with fixed Glorot scale alpha.

    wn = clip(w/alpha, -1, 1); P(+1) = (wn+1)/2; wb in {-alpha, +alpha}.
    """
    wn = jnp.clip(w / alpha, -1.0, 1.0)
    p1 = (wn + 1.0) * 0.5
    u = jax.random.uniform(key, w.shape)
    wb = jnp.where(u < p1, 1.0, -1.0)
    return alpha * wb


def _ours_ternary_raw(w: jnp.ndarray, key, *, alpha: float) -> jnp.ndarray:
    """Eq. 5 + 6: stochastic ternarization with fixed Glorot scale alpha.

    P(nonzero) = |wn|; value = sign(w). wt in {-alpha, 0, +alpha}.
    """
    wn = jnp.clip(w / alpha, -1.0, 1.0)
    p_nz = jnp.abs(wn)
    u = jax.random.uniform(key, w.shape)
    wt = jnp.where(u < p_nz, jnp.sign(wn), 0.0)
    return alpha * wt


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def _binaryconnect_raw(w: jnp.ndarray, key, *, alpha: float) -> jnp.ndarray:
    """BinaryConnect (deterministic): alpha * sign(w).

    The paper's failing baseline (Table 1/3/4/5, Appendix A): no BN, no
    probability reshaping — thresholding only.
    """
    del key
    return alpha * jnp.where(w >= 0, 1.0, -1.0)


def _binaryconnect_stoch_raw(w, key, *, alpha: float):
    """BinaryConnect (stochastic): P(+1) = hard_sigmoid(w/alpha)."""
    p1 = jnp.clip((w / alpha + 1.0) * 0.5, 0.0, 1.0)
    u = jax.random.uniform(key, w.shape)
    return alpha * jnp.where(u < p1, 1.0, -1.0)


def _lab_raw(w: jnp.ndarray, key, **_) -> jnp.ndarray:
    """Loss-aware binarization (Hou et al. 2016), diagonal-curvature
    closed form. With the diagonal Adam second moments approximated as
    uniform, the proximal step reduces to the optimal L2 binarization:
    alpha = E|w| per output column, b = sign(w). (Substitution documented
    in DESIGN.md §3.)"""
    del key
    alpha = jnp.mean(jnp.abs(w), axis=0, keepdims=True)
    return alpha * jnp.where(w >= 0, 1.0, -1.0)


def _twn_raw(w: jnp.ndarray, key, **_) -> jnp.ndarray:
    """Ternary Weight Networks (Li & Liu 2016): threshold 0.7*E|w|,
    scale = mean |w| over the surviving entries (per matrix)."""
    del key
    delta = 0.7 * jnp.mean(jnp.abs(w))
    mask = (jnp.abs(w) > delta).astype(w.dtype)
    denom = jnp.maximum(mask.sum(), 1.0)
    alpha = (jnp.abs(w) * mask).sum() / denom
    return alpha * mask * jnp.sign(w)


def _ttq_raw(w: jnp.ndarray, key, *, wp: jnp.ndarray, wn: jnp.ndarray,
             threshold_frac: float = 0.05) -> jnp.ndarray:
    """Trained Ternary Quantization (Zhu et al. 2016): learned asymmetric
    scales wp (positive side) and wn (negative side); threshold is a fixed
    fraction of max|w|."""
    del key
    delta = threshold_frac * jnp.max(jnp.abs(w))
    pos = (w > delta).astype(w.dtype)
    neg = (w < -delta).astype(w.dtype)
    return wp * pos - wn * neg


def _dorefa_raw(w: jnp.ndarray, key, *, k: int) -> jnp.ndarray:
    """DoReFa-Net k-bit weights (Zhou et al. 2016):
    w_q = 2*quantize_k(tanh(w)/(2 max|tanh(w)|) + 1/2) - 1."""
    del key
    t = jnp.tanh(w)
    x = t / (2.0 * jnp.max(jnp.abs(t)) + 1e-12) + 0.5
    levels = (1 << k) - 1
    q = jnp.round(x * levels) / levels
    return 2.0 * q - 1.0


def _uniform_als_raw(w: jnp.ndarray, key, *, k: int,
                     iters: int = 3) -> jnp.ndarray:
    """LAQ-style k-bit symmetric uniform quantization with the scale fit
    by alternating least squares (per matrix):

        Q = clip(round(w/s), -m, m),  s <- <w,Q>/<Q,Q>,  m = 2^(k-1)-1.

    k=2 gives the ternary LAQ row of Table 1. This is the curvature-free
    relaxation of Hou & Kwok (2018); see DESIGN.md §3.
    """
    del key
    m = (1 << (k - 1)) - 1
    s = jnp.mean(jnp.abs(w)) / max(m, 1) * 2.0 + 1e-12
    for _ in range(iters):
        q = jnp.clip(jnp.round(w / s), -m, m)
        s = (w * q).sum() / jnp.maximum((q * q).sum(), 1e-6)
    q = jnp.clip(jnp.round(w / s), -m, m)
    return s * q


def _alternating_raw(w: jnp.ndarray, key, *, k: int,
                     refine_iters: int = 2) -> jnp.ndarray:
    """Alternating multi-bit binarization (Xu et al. 2018 / Guo et al.
    2017): w ~ sum_z alpha_z * b_z, built greedily on the residual and
    refined by alternating least squares over the k binary codes.

    Costs k binary planes (k x memory, k x ops — reflected in the
    Operations column of Tables 3/4).
    """
    del key
    planes = []
    r = w
    for _ in range(k):
        b = jnp.where(r >= 0, 1.0, -1.0)
        a = jnp.mean(jnp.abs(r))
        planes.append([a, b])
        r = r - a * b
    for _ in range(refine_iters):
        for z in range(k):
            others = sum(a * b for zz, (a, b) in enumerate(planes) if zz != z)
            rz = w - others
            b = jnp.where(rz >= 0, 1.0, -1.0)
            a = jnp.mean(jnp.abs(rz))
            planes[z] = [a, b]
    return sum(a * b for a, b in planes)


def _identity_raw(w: jnp.ndarray, key, **_) -> jnp.ndarray:
    """Full-precision passthrough (the baseline rows)."""
    del key
    return w


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: quantizer name -> (factory(alpha) -> fn(w, key) -> wq, bits-per-weight)
#: ``bits`` drives the Size columns (quant::memory on the rust side uses
#: the same table; keep in sync with rust/src/quant/memory.rs).
REGISTRY: dict[str, tuple[Callable, float]] = {}


def _register(name: str, bits: float, raw_fn: Callable, **fixed):
    needs_alpha = "alpha" in raw_fn.__code__.co_varnames

    def factory(alpha: float) -> Callable:
        kwargs = dict(fixed)
        if needs_alpha:
            kwargs["alpha"] = alpha
        return ste(functools.partial(raw_fn, **kwargs))

    REGISTRY[name] = (factory, bits)


_register("fp", 32.0, _identity_raw)
_register("bin", 1.0, _ours_binary_raw)
_register("ter", 2.0, _ours_ternary_raw)
_register("bc", 1.0, _binaryconnect_raw)
_register("bc_stoch", 1.0, _binaryconnect_stoch_raw)
_register("lab", 1.0, _lab_raw)
_register("twn", 2.0, _twn_raw)
# TTQ's scales are trained parameters — the model binds them via ttq();
# the registry entry only carries the bit width for the Size columns.
REGISTRY["ttq"] = (None, 2.0)
_register("dorefa2", 2.0, _dorefa_raw, k=2)
_register("dorefa3", 3.0, _dorefa_raw, k=3)
_register("dorefa4", 4.0, _dorefa_raw, k=4)
_register("laq2", 2.0, _uniform_als_raw, k=2)
_register("laq3", 3.0, _uniform_als_raw, k=3)
_register("laq4", 4.0, _uniform_als_raw, k=4)
_register("alt1", 1.0, _alternating_raw, k=1)
_register("alt2", 2.0, _alternating_raw, k=2)
_register("alt3", 3.0, _alternating_raw, k=3)
_register("alt4", 4.0, _alternating_raw, k=4)


def get(name: str, alpha: float) -> Callable:
    """Build quantizer ``name`` with Glorot scale ``alpha``.

    Returns ``fn(w, key) -> wq`` with STE backward. TTQ is special-cased
    in the model (its scales are trained parameters).
    """
    factory, _bits = REGISTRY[name]
    return factory(alpha)


def bits(name: str) -> float:
    """Bits per weight for the Size/bandwidth columns."""
    return REGISTRY[name][1]


@jax.custom_vjp
def ttq_apply(w, key, wp, wn):
    """TTQ forward: learned asymmetric scales (Zhu et al. 2016).

    wp/wn are *operands* (not closure captures) so they are first-class
    jit parameters and receive their published gradients:
    dL/dwp = sum over positive-bucket cotangents, dL/dwn = -sum over the
    negative bucket; dL/dw is the bucket-scaled STE.
    """
    del key
    return _ttq_raw(w, None, wp=wp, wn=wn)


def _ttq_fwd(w, key, wp, wn):
    return ttq_apply(w, key, wp, wn), (w, wp, wn)


def _ttq_bwd(res, g):
    w, wp, wn = res
    delta = 0.05 * jnp.max(jnp.abs(w))
    pos = (w > delta).astype(w.dtype)
    neg = (w < -delta).astype(w.dtype)
    mid = 1.0 - pos - neg
    gw = g * (wp * pos + wn * neg + mid)
    return gw, None, (g * pos).sum(), -(g * neg).sum()


ttq_apply.defvjp(_ttq_fwd, _ttq_bwd)


def glorot_alpha(fan_in: int, fan_out: int) -> float:
    """The paper's fixed scale: the Glorot-uniform bound
    sqrt(6/(fan_in+fan_out)) (Glorot & Bengio 2010)."""
    import math
    return math.sqrt(6.0 / (fan_in + fan_out))


#: names whose runtime representation multiplies ops by k (Tables 3/4).
OPS_MULTIPLIER = {"alt1": 1, "alt2": 2, "alt3": 3, "alt4": 4}
