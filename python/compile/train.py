"""Training/eval/inference step builders (the functions AOT-lowered to
HLO artifacts).

Every step is a pure function over flat, name-sorted parameter / state /
optimizer dictionaries so the rust runtime can bind inputs and outputs by
position using the ordering recorded in meta.json. The learning rate and
PRNG seed are runtime *inputs* (scalars): rust owns the LR schedule (the
word-PTB divide-by-4-on-plateau rule lives in the coordinator) and the
stochastic-quantization sampling seed.

Weight updates follow Alg. 1: gradients are taken w.r.t. the quantized
weights and applied (STE) to the full-precision shadow weights, which are
then clipped to [-alpha, alpha] to keep the Bernoulli probabilities of
Eq. 4/5 well-defined.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import layers as L
from . import model as M
from . import quantizers as Q


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adam"       # adam | sgd
    grad_clip: float = 0.0        # global-norm clip (0 = off); word-PTB: 0.25
    weight_clip: bool = True      # clip shadow weights to [-alpha, alpha]
    seq_len: int = 50
    batch: int = 32
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8


# ---------------------------------------------------------------------------
# optimizers (flat-dict native — no optax offline)
# ---------------------------------------------------------------------------

def adam_init(params: dict) -> dict:
    opt = {f"m/{k}": jnp.zeros_like(v) for k, v in params.items()}
    opt.update({f"v/{k}": jnp.zeros_like(v) for k, v in params.items()})
    opt["t"] = jnp.zeros((), jnp.float32)
    return opt


def adam_update(tc: TrainConfig, params, grads, opt, lr):
    t = opt["t"] + 1.0
    out_p, out_o = {}, {"t": t}
    bc1 = 1.0 - tc.adam_b1 ** t
    bc2 = 1.0 - tc.adam_b2 ** t
    for k, g in grads.items():
        m = tc.adam_b1 * opt[f"m/{k}"] + (1.0 - tc.adam_b1) * g
        v = tc.adam_b2 * opt[f"v/{k}"] + (1.0 - tc.adam_b2) * g * g
        step = lr * (m / bc1) / (jnp.sqrt(v / bc2) + tc.adam_eps)
        out_p[k] = params[k] - step
        out_o[f"m/{k}"] = m
        out_o[f"v/{k}"] = v
    return out_p, out_o


def sgd_init(params: dict) -> dict:
    return {"t": jnp.zeros((), jnp.float32)}


def sgd_update(tc: TrainConfig, params, grads, opt, lr):
    out_p = {k: params[k] - lr * g for k, g in grads.items()}
    return out_p, {"t": opt["t"] + 1.0}


def clip_global_norm(grads: dict, max_norm: float) -> dict:
    total = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    scale = jnp.minimum(1.0, max_norm / (total + 1e-12))
    return {k: g * scale for k, g in grads.items()}


def clip_shadow_weights(cfg: M.ModelConfig, params: dict) -> dict:
    """Clip recurrent shadow weights to [-alpha, alpha] (keeps Eq. 4/5
    probabilities in [0, 1]). FP configs are left untouched."""
    if cfg.quantizer == "fp":
        return params
    out = dict(params)
    for name in M.recurrent_weight_names(cfg):
        w = params[name]
        a = Q.glorot_alpha(w.shape[0], w.shape[1])
        out[name] = jnp.clip(w, -a, a)
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def lm_loss(cfg, params, state, xs, ys, key, train):
    """Char/word LM loss (mean CE in nats) + state updates."""
    hs, _, upd, _ = M.rnn_forward(cfg, params, state, xs, key, train)
    logits = M.lm_logits(cfg, params, hs)
    return L.softmax_xent(logits, ys), upd


def classifier_loss(cfg, params, state, xs, ys, key, train):
    """Sequence classification (seq-MNIST): logits from the final hidden
    state. xs: (T, B, D) f32; ys: (B,) int32."""
    hs, _, upd, _ = M.rnn_forward(cfg, params, state, xs, key, train)
    logits = M.classifier_logits(cfg, params, hs[-1])
    loss = L.softmax_xent(logits, ys)
    acc = L.accuracy(logits, ys)
    return loss, (upd, acc)


def attreader_loss(cfg, params, state, doc, query, ys, key, train):
    logits, upd = M.attreader_forward(cfg, params, state, doc, query, key,
                                      train)
    return L.softmax_xent(logits, ys), (upd, L.accuracy(logits, ys))


# ---------------------------------------------------------------------------
# step builders — each returns a pure fn ready for jax.jit(...).lower(...)
# ---------------------------------------------------------------------------

def _merge_state(state: dict, upd: dict) -> dict:
    out = dict(state)
    out.update(upd)
    return out


def build_train_step(cfg: M.ModelConfig, tc: TrainConfig) -> Callable:
    """(params, state, opt, x, y, seed, lr) -> (params, state, opt, loss).

    x: int32 (T, B) tokens for LM heads, f32 (T, B, D) for classifier.
    y: int32 (T, B) for LM, (B,) for classifier.
    """
    update = adam_update if tc.optimizer == "adam" else sgd_update

    def step(params, state, opt, x, y, seed, lr):
        key = jax.random.PRNGKey(seed)

        def lossfn(p):
            if cfg.head == "lm":
                loss, upd = lm_loss(cfg, p, state, x, y, key, True)
            else:
                loss, (upd, _acc) = classifier_loss(cfg, p, state, x, y,
                                                    key, True)
            return loss, upd

        (loss, upd), grads = jax.value_and_grad(lossfn, has_aux=True)(params)
        if tc.grad_clip > 0:
            grads = clip_global_norm(grads, tc.grad_clip)
        new_params, new_opt = update(tc, params, grads, opt, lr)
        if tc.weight_clip:
            new_params = clip_shadow_weights(cfg, new_params)
        return new_params, _merge_state(state, upd), new_opt, loss

    return step


def build_eval_step(cfg: M.ModelConfig) -> Callable:
    """(params, state, x, y, seed) -> loss (mean CE nats).

    Inference mode: running BN statistics, freshly sampled stochastic
    binary/ternary weights (the deployment regime of §5.5 / Fig. 1b).
    """
    def step(params, state, x, y, seed):
        key = jax.random.PRNGKey(seed)
        if cfg.head == "lm":
            loss, _ = lm_loss(cfg, params, state, x, y, key, False)
            return loss
        loss, (_, acc) = classifier_loss(cfg, params, state, x, y, key,
                                         False)
        return loss, acc

    return step


def build_attreader_train_step(cfg: M.ModelConfig, tc: TrainConfig):
    """(params, state, opt, doc, query, y, seed, lr) ->
    (params, state, opt, loss, acc)."""
    update = adam_update if tc.optimizer == "adam" else sgd_update

    def step(params, state, opt, doc, query, y, seed, lr):
        key = jax.random.PRNGKey(seed)

        def lossfn(p):
            loss, (upd, acc) = attreader_loss(cfg, p, state, doc, query, y,
                                              key, True)
            return loss, (upd, acc)

        (loss, (upd, acc)), grads = jax.value_and_grad(
            lossfn, has_aux=True)(params)
        if tc.grad_clip > 0:
            grads = clip_global_norm(grads, tc.grad_clip)
        new_params, new_opt = update(tc, params, grads, opt, lr)
        if tc.weight_clip:
            new_params = clip_shadow_weights(cfg, new_params)
        return new_params, _merge_state(state, upd), new_opt, loss, acc

    return step


def build_attreader_eval_step(cfg: M.ModelConfig):
    def step(params, state, doc, query, y, seed):
        key = jax.random.PRNGKey(seed)
        loss, (_, acc) = attreader_loss(cfg, params, state, doc, query, y,
                                        key, False)
        return loss, acc

    return step


def build_infer_step(cfg: M.ModelConfig) -> Callable:
    """Single-timestep serving step through the fused Pallas cell:

        (params, state, x_onehot, h, c, seed) -> (logits, h', c')

    Weights are stochastically quantized per call (sampled deployment
    weights); BN uses folded running statistics. Single-layer LSTM only —
    the serving configuration.
    """
    def step(params, state, x, h, c, seed):
        key = jax.random.PRNGKey(seed)
        wq = M.quantize_weights(cfg, params, jax.random.fold_in(key, 0x5157))
        if cfg.use_bn:
            h2, c2 = M.kernel_infer_step(cfg, params, state, wq, x, h, c)
        else:
            # vanilla cell (baseline serving) — same kernel, identity BN
            n4 = 4 * cfg.hidden
            ones, zeros = jnp.ones(n4), jnp.zeros(n4)
            from .kernels import bnlstm_cell as cell
            h2, c2 = cell(x, h, c, wq["l0/wx"], wq["l0/wh"],
                          ones, zeros, ones, zeros, params["l0/b"])
        logits = M.classifier_logits(cfg, params, h2) if cfg.head != "lm" \
            else h2 @ params["head/w"] + params["head/b"]
        return logits, h2, c2

    return step


def build_gate_stats_step(cfg: M.ModelConfig) -> Callable:
    """(params, state, x, seed, train_mode) -> (i, f, o, g, i_pre, h).

    Dumps layer-0 gate activations (T, B, H) for the Appendix-A density
    figures. train_mode selects batch-vs-running BN statistics.
    """
    def step(params, state, x, seed):
        key = jax.random.PRNGKey(seed)
        _, _, _, tr = M.rnn_forward(cfg, params, state, x, key, True,
                                    collect_gates=True)
        return (tr["i"], tr["f"], tr["o"], tr["g"], tr["i_pre"], tr["h"])

    return step


def init_opt(tc: TrainConfig, params: dict) -> dict:
    return adam_init(params) if tc.optimizer == "adam" else sgd_init(params)
