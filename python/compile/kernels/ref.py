"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package is checked against the functions here by
``python/tests/test_kernels.py`` (assert_allclose + hypothesis sweeps).
These are the ground truth for L1 numerics; the L2 model calls the same
math through ``layers.py``.
"""

from __future__ import annotations

import jax.numpy as jnp


def qmatmul_ref(x: jnp.ndarray, wq: jnp.ndarray) -> jnp.ndarray:
    """Quantized matmul oracle: x (m, k) @ wq (k, n) -> (m, n) in f32.

    ``wq`` holds the already-quantized weights as f32 values in
    {-1, 0, +1} scaled by alpha; the kernel must reproduce a plain f32
    contraction bit-for-bit (same accumulation dtype).
    """
    return jnp.dot(x.astype(jnp.float32), wq.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def bn_apply_ref(y: jnp.ndarray, mean: jnp.ndarray, var: jnp.ndarray,
                 phi: jnp.ndarray, gamma: jnp.ndarray,
                 eps: float = 1e-5) -> jnp.ndarray:
    """Batch-norm *apply* oracle (Eq. 3 with precomputed statistics).

    y: (m, n); mean/var/phi/gamma: (n,). The paper's convention: phi is the
    learned gain, gamma the learned shift (zero for the gate transforms).
    """
    inv = phi / jnp.sqrt(var + eps)
    return gamma + (y - mean) * inv


def qmatmul_bn_ref(x, wq, mean, var, phi, gamma, eps: float = 1e-5):
    """Fused Eq. 7 hot path oracle: BN(x @ Wq; phi, gamma)."""
    return bn_apply_ref(qmatmul_ref(x, wq), mean, var, phi, gamma, eps)


def lstm_cell_ref(xw, hw, b, c_prev,
                  phi_c=None, gamma_c=None, eps: float = 1e-5):
    """LSTM cell tail oracle given fused pre-activations.

    xw, hw: (batch, 4*hidden) — the (already batch-normalized) results of
    the input and recurrent quantized matmuls, gate order [i, f, g, o].
    b: (4*hidden,) bias. Returns (h, c).

    When phi_c/gamma_c are given, the cell state is batch-normalized
    before the output tanh (Alg. 1 line 13, the optional BN(c)).
    """
    pre = xw + hw + b
    h4 = pre.shape[-1] // 4
    i = jnp.reciprocal(1.0 + jnp.exp(-pre[..., 0 * h4:1 * h4]))
    f = jnp.reciprocal(1.0 + jnp.exp(-pre[..., 1 * h4:2 * h4]))
    g = jnp.tanh(pre[..., 2 * h4:3 * h4])
    o = jnp.reciprocal(1.0 + jnp.exp(-pre[..., 3 * h4:4 * h4]))
    c = f * c_prev + i * g
    if phi_c is not None:
        mean = jnp.mean(c, axis=0, keepdims=True)
        var = jnp.var(c, axis=0, keepdims=True)
        c_bn = gamma_c + phi_c * (c - mean) / jnp.sqrt(var + eps)
        h = o * jnp.tanh(c_bn)
    else:
        h = o * jnp.tanh(c)
    return h, c


def pack_ternary_ref(wq: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bit-plane packing oracle for ternary weights.

    wq: (k, n) f32 in {-1, 0, +1}. Returns (sign_plane, mask_plane) as
    uint8 arrays of shape (ceil(k/8), n): bit b of row r covers wq[8r+b].
    mask bit = |w|, sign bit = (w > 0). Matches rust `quant::pack`.
    """
    k, n = wq.shape
    kp = (k + 7) // 8 * 8
    wpad = jnp.pad(wq, ((0, kp - k), (0, 0)))
    mask = (wpad != 0).astype(jnp.uint8)
    sign = (wpad > 0).astype(jnp.uint8)
    shifts = (jnp.arange(kp, dtype=jnp.uint8) % 8)[:, None]
    rows = jnp.arange(kp) // 8

    def plane(bits):
        weighted = (bits << shifts).astype(jnp.uint8)
        out = jnp.zeros(((kp // 8), n), dtype=jnp.uint8)
        return out.at[rows].add(weighted)

    return plane(sign), plane(mask)
