"""Pallas kernels for the quantized gate pre-activation hot path.

The paper's ASIC replaces 12-bit multipliers with multiplexers because the
weights are in {-1, 0, +1}. The TPU translation (DESIGN.md
§Hardware-Adaptation): weights ride the MXU as ±1/0 values at full matmul
rate, so compute cost is unchanged and the entire win moves to the memory
system — weights are stored bit-packed in HBM (1 b binary / 2 b ternary)
and unpacked in-register after the HBM→VMEM stream expressed by the
BlockSpec grid below.

All kernels are built with ``interpret=True``: this image's PJRT plugin is
CPU-only and cannot execute Mosaic custom-calls; interpret mode lowers to
plain HLO so the exact same program runs under the rust PJRT client.
Real-TPU performance is *estimated* in DESIGN.md §9 / EXPERIMENTS.md §Perf
from the VMEM footprint + MXU-utilization model in ``vmem_model`` below.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Block-size selection / VMEM model
# ---------------------------------------------------------------------------

class BlockPlan(NamedTuple):
    """Tile sizes for the (m, k) x (k, n) contraction."""
    bm: int
    bk: int
    bn: int

    def vmem_bytes(self, packed_bits: int = 2) -> int:
        """Estimated VMEM residency with double buffering.

        x tile f32 + packed weight tile (packed_bits per element, int8
        carrier) + f32 accumulator tile; input tiles are double-buffered.
        """
        x_tile = self.bm * self.bk * 4
        w_tile = self.bk * self.bn * packed_bits // 8
        acc = self.bm * self.bn * 4
        return 2 * (x_tile + w_tile) + acc

    def mxu_utilization(self, m: int, k: int, n: int) -> float:
        """MXU busy-fraction estimate for the full problem.

        The 128x128 systolic array retires one 128x128x1 MAC slab per
        cycle; tiles narrower than 128 in m or n waste lanes. Grid-edge
        remainders are modeled by ceil-division.
        """
        gm, gk, gn = (math.ceil(m / self.bm), math.ceil(k / self.bk),
                      math.ceil(n / self.bn))
        useful = m * k * n
        lanes_m = min(self.bm, 128)
        lanes_n = min(self.bn, 128)
        cycles_per_tile = (math.ceil(self.bm / 128) * math.ceil(self.bn / 128)
                           * self.bk)
        total_cycles = gm * gk * gn * cycles_per_tile
        issued = total_cycles * 128 * 128
        occupancy = (lanes_m / min(self.bm, 128)) * (lanes_n / min(self.bn, 128))
        return min(1.0, useful / issued) * occupancy


def choose_block_plan(m: int, k: int, n: int,
                      vmem_budget: int = 16 * 2 ** 20,
                      packed_bits: int = 2) -> BlockPlan:
    """Pick the largest MXU-aligned plan that fits the VMEM budget.

    Preference order: maximize bn and bk (weight-stationary streaming of
    the packed planes), then bm; all rounded to the 8/128 TPU lane grid
    when the problem is large enough to allow it.
    """
    def align(x: int, q: int) -> int:
        return max(q, (x // q) * q) if x >= q else x

    best = None
    for bm in (align(m, 8), min(m, 128), min(m, 256)):
        for bk in (min(k, 128), min(k, 256), min(k, 512)):
            for bn in (min(n, 128), min(n, 256), min(n, 512)):
                plan = BlockPlan(max(1, bm), max(1, bk), max(1, bn))
                if plan.vmem_bytes(packed_bits) > vmem_budget:
                    continue
                score = (plan.mxu_utilization(m, k, n),
                         plan.bn * plan.bk, plan.bm)
                if best is None or score > best[0]:
                    best = (score, plan)
    assert best is not None, "no feasible block plan"
    return best[1]


# ---------------------------------------------------------------------------
# qmatmul: tiled x @ Wq
# ---------------------------------------------------------------------------

def _qmatmul_kernel(x_ref, w_ref, o_ref, *, gk: int):
    """Grid (gm, gn, gk); the output block is revisited across the k steps
    (its index map ignores ki), so it doubles as the f32 accumulator —
    the output tile stays resident in VMEM for the whole contraction."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def _fit_divisor(dim: int, want: int) -> int:
    """Largest block size <= want that divides dim exactly.

    The accumulate-into-output-block pattern requires every grid step to
    cover a full block: non-dividing tiles would re-accumulate padding at
    the grid edge. Snapping to a divisor keeps arbitrary BlockPlans safe.
    """
    want = max(1, min(want, dim))
    for d in range(want, 0, -1):
        if dim % d == 0:
            return d
    return 1


def qmatmul(x: jnp.ndarray, wq: jnp.ndarray,
            plan: BlockPlan | None = None) -> jnp.ndarray:
    """Tiled quantized matmul: x (m, k) @ wq (k, n) -> (m, n), f32.

    ``wq`` carries ±1/0 (times alpha) as f32; numerics must match
    ``ref.qmatmul_ref`` exactly (same f32 accumulation).
    """
    m, k = x.shape
    k2, n = wq.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    if plan is None:
        plan = choose_block_plan(m, k, n)
    bm, bk, bn = (_fit_divisor(m, plan.bm), _fit_divisor(k, plan.bk),
                  _fit_divisor(n, plan.bn))
    gm, gk, gn = (m // bm, k // bk, n // bn)

    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, gk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), wq.astype(jnp.float32))


# ---------------------------------------------------------------------------
# qmatmul_bn: fused BN(x @ Wq; phi, gamma) with precomputed statistics
# ---------------------------------------------------------------------------

def _qmatmul_bn_kernel(x_ref, w_ref, scale_ref, shift_ref, o_ref,
                       *, gk: int):
    """Same contraction grid as _qmatmul_kernel; the BN affine transform is
    folded into a per-output-column (scale, shift) pair applied at flush
    time, so the normalization costs one FMA per output element and zero
    extra HBM traffic for the statistics."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(ki == gk - 1)
    def _flush():
        o_ref[...] = o_ref[...] * scale_ref[...] + shift_ref[...]


def qmatmul_bn(x: jnp.ndarray, wq: jnp.ndarray, mean: jnp.ndarray,
               var: jnp.ndarray, phi: jnp.ndarray, gamma: jnp.ndarray,
               eps: float = 1e-5, plan: BlockPlan | None = None) -> jnp.ndarray:
    """Fused Eq. 7 hot path: BN(x @ Wq; phi, gamma) with given statistics.

    BN(y) = gamma + phi * (y - mean) / sqrt(var + eps) is refactored to
    y * scale + shift with scale = phi * rsqrt(var + eps) and
    shift = gamma - mean * scale — the canonical inference-time BN fold.
    """
    m, k = x.shape
    _, n = wq.shape
    scale = (phi / jnp.sqrt(var + eps)).astype(jnp.float32)
    shift = (gamma - mean * scale).astype(jnp.float32)
    if plan is None:
        plan = choose_block_plan(m, k, n)
    bm, bk, bn = (_fit_divisor(m, plan.bm), _fit_divisor(k, plan.bk),
                  _fit_divisor(n, plan.bn))
    gm, gk, gn = (m // bm, k // bk, n // bn)

    return pl.pallas_call(
        functools.partial(_qmatmul_bn_kernel, gk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((1, bn), lambda i, j, ki: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, ki: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), wq.astype(jnp.float32),
      scale.reshape(1, n), shift.reshape(1, n))


# ---------------------------------------------------------------------------
# custom-VJP wrapper so training graphs can also route through the kernel
# ---------------------------------------------------------------------------

@jax.custom_vjp
def qmatmul_ste(x: jnp.ndarray, wq: jnp.ndarray) -> jnp.ndarray:
    """qmatmul with a hand-written VJP (the kernel itself has no autodiff
    rule).  Gradients are the standard matmul cotangents; combined with the
    straight-through estimator in ``quantizers.py`` this realizes Eq. 1."""
    return qmatmul(x, wq)


def _qmatmul_ste_fwd(x, wq):
    return qmatmul(x, wq), (x, wq)


def _qmatmul_ste_bwd(res, g):
    x, wq = res
    return (jnp.dot(g, wq.T, preferred_element_type=jnp.float32),
            jnp.dot(x.T, g, preferred_element_type=jnp.float32))


qmatmul_ste.defvjp(_qmatmul_ste_fwd, _qmatmul_ste_bwd)
