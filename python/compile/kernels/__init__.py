"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .quant_matmul import (BlockPlan, choose_block_plan, qmatmul,
                           qmatmul_bn, qmatmul_ste)
from .bnlstm_cell import bnlstm_cell, fold_bn

__all__ = [
    "BlockPlan", "choose_block_plan", "qmatmul", "qmatmul_bn",
    "qmatmul_ste", "bnlstm_cell", "fold_bn",
]
