"""Fused BN-LSTM cell Pallas kernel.

One kernel invocation computes a full Eq. 7 cell update:

    pre = BN(x @ Wx_q) + BN(h @ Wh_q) + b        (two quantized matmuls,
    i, f, g, o = split(pre)                       BN folded to scale/shift)
    c' = f*c + i*g ;  h' = o * tanh(c')

Fusing the cell keeps the gate block (batch x 4H) in VMEM between the
matmuls and the elementwise tail — on real TPU this removes two HBM
round-trips of the pre-activation tensor per timestep, which dominates the
timestep latency for the small-batch serving regime the paper's high-speed
engine targets (Appendix D / Fig. 7).

The grid partitions the batch only; each program owns the full (4H)-wide
gate slab so the nonlinear tail never crosses block boundaries. This caps
H at VMEM/(4*4*3) per program — ≥ 8k hidden units, far beyond the paper's
2000-unit largest model (the VMEM table lives in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref,
                 sx_ref, tx_ref, sh_ref, th_ref, b_ref,
                 h_out_ref, c_out_ref):
    """Single-program fused cell over one batch tile."""
    xw = jnp.dot(x_ref[...], wx_ref[...], preferred_element_type=jnp.float32)
    hw = jnp.dot(h_ref[...], wh_ref[...], preferred_element_type=jnp.float32)
    pre = (xw * sx_ref[...] + tx_ref[...]) \
        + (hw * sh_ref[...] + th_ref[...]) + b_ref[...]

    hid = c_ref.shape[-1]
    i = jax.nn.sigmoid(pre[:, 0 * hid:1 * hid])
    f = jax.nn.sigmoid(pre[:, 1 * hid:2 * hid])
    g = jnp.tanh(pre[:, 2 * hid:3 * hid])
    o = jax.nn.sigmoid(pre[:, 3 * hid:4 * hid])

    c_new = f * c_ref[...] + i * g
    c_out_ref[...] = c_new
    h_out_ref[...] = o * jnp.tanh(c_new)


def bnlstm_cell(x, h, c, wx_q, wh_q, scale_x, shift_x, scale_h, shift_h,
                bias, block_batch: int | None = None):
    """Fused BN-LSTM cell step.

    x: (B, Dx); h, c: (B, H); wx_q: (Dx, 4H); wh_q: (H, 4H) — quantized
    (±alpha/0) weights as f32. scale/shift: (4H,) folded BN statistics for
    the input and recurrent paths. bias: (4H,). Gate order [i, f, g, o].
    Returns (h', c').
    """
    batch, dx = x.shape
    hid = h.shape[-1]
    n4 = 4 * hid
    assert wx_q.shape == (dx, n4) and wh_q.shape == (hid, n4)
    bb = min(batch, block_batch or 128)
    grid = (pl.cdiv(batch, bb),)

    row = lambda v: v.reshape(1, n4).astype(jnp.float32)
    kernel = functools.partial(_cell_kernel)

    h_new, c_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, dx), lambda i: (i, 0)),
            pl.BlockSpec((bb, hid), lambda i: (i, 0)),
            pl.BlockSpec((bb, hid), lambda i: (i, 0)),
            pl.BlockSpec((dx, n4), lambda i: (0, 0)),
            pl.BlockSpec((hid, n4), lambda i: (0, 0)),
            pl.BlockSpec((1, n4), lambda i: (0, 0)),
            pl.BlockSpec((1, n4), lambda i: (0, 0)),
            pl.BlockSpec((1, n4), lambda i: (0, 0)),
            pl.BlockSpec((1, n4), lambda i: (0, 0)),
            pl.BlockSpec((1, n4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, hid), lambda i: (i, 0)),
            pl.BlockSpec((bb, hid), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, hid), jnp.float32),
            jax.ShapeDtypeStruct((batch, hid), jnp.float32),
        ],
        interpret=True,
    )(x.astype(jnp.float32), h.astype(jnp.float32), c.astype(jnp.float32),
      wx_q.astype(jnp.float32), wh_q.astype(jnp.float32),
      row(scale_x), row(shift_x), row(scale_h), row(shift_h), row(bias))
    return h_new, c_new


def fold_bn(mean, var, phi, gamma, eps: float = 1e-5):
    """Fold BN statistics into (scale, shift): BN(y) == y*scale + shift."""
    scale = phi / jnp.sqrt(var + eps)
    return scale, gamma - mean * scale
