"""L2 model zoo: the paper's BN-LSTM / BN-GRU with learned recurrent
binary/ternary weights (Eq. 7 / Alg. 1), the vanilla baselines, and the
Attentive Reader for the CNN question-answering task (§5.4).

Every architecture is a pure function over a flat parameter dict plus a
flat BN-running-statistics state dict. Weight quantization happens once
per forward pass (Alg. 1 lines 3-6), then the scan reuses the quantized
matrices for every timestep — matching the paper and keeping inference
memory at 1-2 bits/weight.

Gate order for LSTM matrices is [i, f, g, o]; for GRU it is [z, r, n].
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import quantizers as Q
from .kernels import bnlstm_cell as _pallas_cell
from .kernels import fold_bn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration for one experiment model."""
    arch: str = "bnlstm"          # bnlstm | lstm | bngru | gru
    quantizer: str = "ter"        # see quantizers.REGISTRY
    vocab: int = 50               # token vocabulary (0 => continuous input)
    input_dim: int = 0            # continuous input width (seq-MNIST: 1)
    emb_dim: int = 0              # 0 => one-hot/continuous input, no embedding
    hidden: int = 96
    num_layers: int = 1
    head: str = "lm"              # lm | classifier | attreader
    num_classes: int = 0          # classifier/attreader output size
    dropout: float = 0.0          # non-recurrent dropout (Zaremba-style)
    bn_cell: bool = False         # optional BN(c) (Alg. 1 line 13)
    use_kernel: bool = False      # route inference through the Pallas cell

    @property
    def use_bn(self) -> bool:
        return self.arch in ("bnlstm", "bngru")

    @property
    def is_gru(self) -> bool:
        return self.arch in ("bngru", "gru")

    @property
    def gates(self) -> int:
        return 3 if self.is_gru else 4

    def layer_input_dim(self, layer: int) -> int:
        if layer > 0:
            return self.hidden
        if self.emb_dim:
            return self.emb_dim
        if self.input_dim:
            return self.input_dim
        return self.vocab


# ---------------------------------------------------------------------------
# parameter / state construction
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Build (params, state) for ``cfg``.

    Forget-gate bias starts at 1.0 (standard LSTM practice); BN gains phi
    start at 0.1 per Cooijmans et al. (2016), which the paper builds on.
    """
    params: dict[str, jnp.ndarray] = {}
    state: dict[str, jnp.ndarray] = {}
    g = cfg.gates
    keys = iter(jax.random.split(key, 64))

    for l in range(cfg.num_layers):
        d = cfg.layer_input_dim(l)
        h = cfg.hidden
        p = f"l{l}"
        params[f"{p}/wx"] = L.glorot_uniform(next(keys), (d, g * h))
        params[f"{p}/wh"] = L.glorot_uniform(next(keys), (h, g * h))
        bias = jnp.zeros(g * h)
        if not cfg.is_gru:
            bias = bias.at[h:2 * h].set(1.0)  # forget gate
        params[f"{p}/b"] = bias
        if cfg.quantizer == "ttq":
            for mat in ("x", "h"):
                params[f"{p}/ttq_wp_{mat}"] = jnp.asarray(1.0)
                params[f"{p}/ttq_wn_{mat}"] = jnp.asarray(1.0)
        if cfg.use_bn:
            params[f"{p}/phi_x"] = jnp.full(g * h, 0.1)
            params[f"{p}/phi_h"] = jnp.full(g * h, 0.1)
            state[f"{p}/rm_x"] = jnp.zeros(g * h)
            state[f"{p}/rv_x"] = jnp.ones(g * h)
            state[f"{p}/rm_h"] = jnp.zeros(g * h)
            state[f"{p}/rv_h"] = jnp.ones(g * h)
            if cfg.bn_cell and not cfg.is_gru:
                params[f"{p}/phi_c"] = jnp.full(h, 0.1)
                params[f"{p}/gamma_c"] = jnp.zeros(h)
                state[f"{p}/rm_c"] = jnp.zeros(h)
                state[f"{p}/rv_c"] = jnp.ones(h)

    if cfg.emb_dim:
        params["emb/emb"] = 0.1 * jax.random.normal(
            next(keys), (cfg.vocab, cfg.emb_dim), jnp.float32)

    if cfg.head == "lm":
        params["head/w"] = L.glorot_uniform(next(keys), (cfg.hidden, cfg.vocab))
        params["head/b"] = jnp.zeros(cfg.vocab)
    elif cfg.head == "classifier":
        params["head/w"] = L.glorot_uniform(next(keys),
                                            (cfg.hidden, cfg.num_classes))
        params["head/b"] = jnp.zeros(cfg.num_classes)
    elif cfg.head == "attreader":
        h2 = 2 * cfg.hidden
        params["att/w_ym"] = L.glorot_uniform(next(keys), (h2, h2))
        params["att/w_um"] = L.glorot_uniform(next(keys), (h2, h2))
        params["att/w_ms"] = L.glorot_uniform(next(keys), (h2, 1))
        params["att/w_rg"] = L.glorot_uniform(next(keys), (h2, h2))
        params["att/w_ug"] = L.glorot_uniform(next(keys), (h2, h2))
        params["head/w"] = L.glorot_uniform(next(keys), (h2, cfg.num_classes))
        params["head/b"] = jnp.zeros(cfg.num_classes)
    else:
        raise ValueError(f"unknown head {cfg.head}")
    return params, state


def recurrent_weight_names(cfg: ModelConfig) -> list[str]:
    """The matrices the paper quantizes (and whose bytes every Size column
    counts): the input and recurrent weights of each RNN layer."""
    out = []
    for l in range(cfg.num_layers):
        out += [f"l{l}/wx", f"l{l}/wh"]
    return out


# ---------------------------------------------------------------------------
# quantization of the recurrent weights (Alg. 1 lines 3-6)
# ---------------------------------------------------------------------------

def quantize_weights(cfg: ModelConfig, params: dict, key) -> dict:
    """Sample quantized versions of every recurrent matrix.

    Returns {name: quantized array}; the scale alpha is the per-matrix
    Glorot bound (the paper's fixed alpha). FP configs return the shadow
    weights unchanged.
    """
    out = {}
    for i, name in enumerate(recurrent_weight_names(cfg)):
        w = params[name]
        sub = jax.random.fold_in(key, i)
        if cfg.quantizer == "ttq":
            layer, mat = name.split("/")
            suffix = mat[1]  # wx -> x, wh -> h
            out[name] = Q.ttq_apply(w, sub,
                                    params[f"{layer}/ttq_wp_{suffix}"],
                                    params[f"{layer}/ttq_wn_{suffix}"])
        else:
            alpha = Q.glorot_alpha(w.shape[0], w.shape[1])
            qfn = Q.get(cfg.quantizer, alpha)
            out[name] = qfn(w, sub)
    return out


# ---------------------------------------------------------------------------
# recurrent cores
# ---------------------------------------------------------------------------

def _input_preact(cfg, params, wq, layer, xs):
    """xw for all timesteps at once.

    xs is int32 tokens (T, B) when this layer sits on a one-hot input, else
    f32 (T, B, D). The token path gathers rows of the quantized matrix —
    numerically identical to the one-hot matmul, and exactly what the
    paper's accelerator does with its weight SRAM addressing.
    """
    wx_q = wq[f"l{layer}/wx"]
    if xs.dtype in (jnp.int32, jnp.int64):
        return wx_q[xs]
    return xs @ wx_q


def _bn_seq_train(seq, phi):
    """Vectorized per-timestep training BN for a (T, B, N) tensor.

    Returns (normalized, mean-of-means, mean-of-vars) — the per-step batch
    statistics averaged over T for the EMA state update.
    """
    mean = jnp.mean(seq, axis=1, keepdims=True)
    var = jnp.var(seq, axis=1, keepdims=True)
    y = phi * (seq - mean) / jnp.sqrt(var + L.BN_EPS)
    return y, jnp.mean(mean[:, 0, :], axis=0), jnp.mean(var[:, 0, :], axis=0)


def lstm_layer(cfg, params, state, wq, layer, xs, h0, c0, train):
    """One (BN-)LSTM layer over a full sequence.

    xs: tokens (T, B) or features (T, B, D). Returns
    (hs (T,B,H), (h_T, c_T), state_updates dict, gate_trace dict).
    gate_trace carries per-step gate activations for the Appendix-A
    figures; entries are (T, B, H) tensors.
    """
    p = f"l{layer}"
    h = cfg.hidden
    wh_q = wq[f"{p}/wh"]
    b = params[f"{p}/b"]
    xw = _input_preact(cfg, params, wq, layer, xs)  # (T, B, 4H)

    updates: dict[str, jnp.ndarray] = {}
    if cfg.use_bn:
        if train:
            xw_n, mx, vx = _bn_seq_train(xw, params[f"{p}/phi_x"])
            updates[f"{p}/rm_x"] = L.ema_update(state[f"{p}/rm_x"], mx)
            updates[f"{p}/rv_x"] = L.ema_update(state[f"{p}/rv_x"], vx)
        else:
            xw_n = L.bn_infer(xw, params[f"{p}/phi_x"], 0.0,
                              state[f"{p}/rm_x"], state[f"{p}/rv_x"])
    else:
        xw_n = xw

    phi_h = params.get(f"{p}/phi_h")
    phi_c = params.get(f"{p}/phi_c")
    gamma_c = params.get(f"{p}/gamma_c")

    def step(carry, xw_t):
        hprev, cprev = carry
        hw = hprev @ wh_q
        if cfg.use_bn:
            if train:
                hw_n, mh, vh = L.bn_train(hw, phi_h, 0.0)
            else:
                hw_n = L.bn_infer(hw, phi_h, 0.0,
                                  state[f"{p}/rm_h"], state[f"{p}/rv_h"])
                mh = vh = jnp.zeros(hw.shape[-1])
        else:
            hw_n = hw
            mh = vh = jnp.zeros(hw.shape[-1])
        pre = xw_t + hw_n + b
        i = jax.nn.sigmoid(pre[:, 0 * h:1 * h])
        f = jax.nn.sigmoid(pre[:, 1 * h:2 * h])
        g = jnp.tanh(pre[:, 2 * h:3 * h])
        o = jax.nn.sigmoid(pre[:, 3 * h:4 * h])
        c = f * cprev + i * g
        if phi_c is not None:
            if train:
                c_n, mc, vc = L.bn_train(c, phi_c, gamma_c)
            else:
                c_n = L.bn_infer(c, phi_c, gamma_c,
                                 state[f"{p}/rm_c"], state[f"{p}/rv_c"])
                mc = vc = jnp.zeros(h)
        else:
            c_n = c
            mc = vc = jnp.zeros(h)
        hnew = o * jnp.tanh(c_n)
        ip = pre[:, 0 * h:1 * h]
        return (hnew, c), (hnew, (mh, vh, mc, vc), (i, f, o, g, ip))

    (hT, cT), (hs, stats, gates) = jax.lax.scan(step, (h0, c0), xw_n)

    if cfg.use_bn and train:
        mh, vh, mc, vc = (jnp.mean(s, axis=0) for s in stats)
        updates[f"{p}/rm_h"] = L.ema_update(state[f"{p}/rm_h"], mh)
        updates[f"{p}/rv_h"] = L.ema_update(state[f"{p}/rv_h"], vh)
        if phi_c is not None:
            updates[f"{p}/rm_c"] = L.ema_update(state[f"{p}/rm_c"], mc)
            updates[f"{p}/rv_c"] = L.ema_update(state[f"{p}/rv_c"], vc)

    i, f, o, g, ip = gates
    trace = {"i": i, "f": f, "o": o, "g": g, "i_pre": ip, "h": hs}
    return hs, (hT, cT), updates, trace


def gru_layer(cfg, params, state, wq, layer, xs, h0, train):
    """One (BN-)GRU layer over a full sequence. Gate order [z, r, n]."""
    p = f"l{layer}"
    h = cfg.hidden
    wh_q = wq[f"{p}/wh"]
    b = params[f"{p}/b"]
    xw = _input_preact(cfg, params, wq, layer, xs)  # (T, B, 3H)

    updates: dict[str, jnp.ndarray] = {}
    if cfg.use_bn:
        if train:
            xw_n, mx, vx = _bn_seq_train(xw, params[f"{p}/phi_x"])
            updates[f"{p}/rm_x"] = L.ema_update(state[f"{p}/rm_x"], mx)
            updates[f"{p}/rv_x"] = L.ema_update(state[f"{p}/rv_x"], vx)
        else:
            xw_n = L.bn_infer(xw, params[f"{p}/phi_x"], 0.0,
                              state[f"{p}/rm_x"], state[f"{p}/rv_x"])
    else:
        xw_n = xw

    phi_h = params.get(f"{p}/phi_h")

    def step(carry, xw_t):
        hprev = carry
        hw = hprev @ wh_q
        if cfg.use_bn:
            if train:
                hw_n, mh, vh = L.bn_train(hw, phi_h, 0.0)
            else:
                hw_n = L.bn_infer(hw, phi_h, 0.0,
                                  state[f"{p}/rm_h"], state[f"{p}/rv_h"])
                mh = vh = jnp.zeros(hw.shape[-1])
        else:
            hw_n = hw
            mh = vh = jnp.zeros(hw.shape[-1])
        z = jax.nn.sigmoid(xw_t[:, 0 * h:1 * h] + hw_n[:, 0 * h:1 * h]
                           + b[0 * h:1 * h])
        r = jax.nn.sigmoid(xw_t[:, 1 * h:2 * h] + hw_n[:, 1 * h:2 * h]
                           + b[1 * h:2 * h])
        n = jnp.tanh(xw_t[:, 2 * h:3 * h] + r * hw_n[:, 2 * h:3 * h]
                     + b[2 * h:3 * h])
        hnew = (1.0 - z) * hprev + z * n
        return hnew, (hnew, (mh, vh))

    hT, (hs, stats) = jax.lax.scan(step, h0, xw_n)
    if cfg.use_bn and train:
        mh, vh = (jnp.mean(s, axis=0) for s in stats)
        updates[f"{p}/rm_h"] = L.ema_update(state[f"{p}/rm_h"], mh)
        updates[f"{p}/rv_h"] = L.ema_update(state[f"{p}/rv_h"], vh)
    return hs, hT, updates, {}


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------

def rnn_forward(cfg: ModelConfig, params, state, xs, key, train,
                h0=None, c0=None, collect_gates: bool = False):
    """Stacked RNN over a sequence.

    xs: int32 tokens (T, B) or f32 features (T, B, D).
    Returns (hs_top (T,B,H), finals, state_updates, gate_trace).
    finals: list of (h, c) per layer (LSTM) or h per layer (GRU).
    """
    kq, kdrop = jax.random.split(jax.random.fold_in(key, 0x5157))
    wq = quantize_weights(cfg, params, kq)
    batch = xs.shape[1]
    cur = xs
    if cfg.emb_dim:
        cur = L.embedding(params, "emb", cur)
    if train and cfg.dropout > 0 and cfg.emb_dim:
        cur = L.dropout(jax.random.fold_in(kdrop, 99), cur, cfg.dropout)

    updates: dict[str, jnp.ndarray] = {}
    finals = []
    trace = {}
    for l in range(cfg.num_layers):
        if cfg.is_gru:
            hl = h0[l] if h0 is not None else jnp.zeros((batch, cfg.hidden))
            hs, hT, upd, tr = gru_layer(cfg, params, state, wq, l, cur,
                                        hl, train)
            finals.append(hT)
        else:
            hl = h0[l] if h0 is not None else jnp.zeros((batch, cfg.hidden))
            cl = c0[l] if c0 is not None else jnp.zeros((batch, cfg.hidden))
            hs, (hT, cT), upd, tr = lstm_layer(cfg, params, state, wq, l,
                                               cur, hl, cl, train)
            finals.append((hT, cT))
        updates.update(upd)
        if collect_gates and l == 0:
            trace = tr
        cur = hs
        if train and cfg.dropout > 0:
            cur = L.dropout(jax.random.fold_in(kdrop, l), cur, cfg.dropout)
    return cur, finals, updates, trace


def lm_logits(cfg, params, hs):
    """(T, B, H) -> (T, B, V)."""
    return hs @ params["head/w"] + params["head/b"]


def classifier_logits(cfg, params, h_last):
    """(B, H) -> (B, C)."""
    return h_last @ params["head/w"] + params["head/b"]


# ---------------------------------------------------------------------------
# Attentive Reader (Hermann et al. 2015) for the CNN-QA task (§5.4)
# ---------------------------------------------------------------------------

def _bilstm(cfg, params, state, xs, key, train):
    """Bidirectional single-layer LSTM; returns per-token (T, B, 2H) and
    the (fwd-last ++ bwd-first) summary (B, 2H).

    Uses layer 0 for the forward direction and layer 1 for the backward
    direction (two independent parameter sets, as in the paper's
    two-bidirectional-LSTM reader).
    """
    sub = dataclasses.replace(cfg, num_layers=1)
    kf, kb = jax.random.split(key)
    # forward direction: layer-0 params
    hs_f, fin_f, upd_f, _ = rnn_forward(
        sub, params, state, xs, kf, train)
    # backward direction: reverse time, run layer-0 of the 'bwd/' params
    xs_rev = jnp.flip(xs, axis=0)
    bwd_params = {k[4:]: v for k, v in params.items() if k.startswith("bwd/")}
    bwd_state = {k[4:]: v for k, v in state.items() if k.startswith("bwd/")}
    hs_b, fin_b, upd_b, _ = rnn_forward(
        sub, bwd_params, bwd_state, xs_rev, kb, train)
    hs_b = jnp.flip(hs_b, axis=0)
    ys = jnp.concatenate([hs_f, hs_b], axis=-1)
    summary = jnp.concatenate([fin_f[0][0], fin_b[0][0]], axis=-1)
    upd = dict(upd_f)
    upd.update({f"bwd/{k}": v for k, v in upd_b.items()})
    return ys, summary, upd


def attreader_forward(cfg: ModelConfig, params, state, doc, query, key,
                      train):
    """Attentive Reader: encode doc + query with bidirectional (BN-)LSTMs,
    attend, and classify the answer entity.

    doc: (Td, B) int32; query: (Tq, B) int32. Returns (logits (B, C),
    state_updates).
    """
    kd, kq2 = jax.random.split(jax.random.fold_in(key, 0xA77))
    ys, _, upd_d = _bilstm(cfg, params, state, doc, kd, train)      # (Td,B,2H)
    _, u, upd_q = _bilstm(cfg, {k[6:]: v for k, v in params.items()
                                if k.startswith("query/")},
                          {k[6:]: v for k, v in state.items()
                           if k.startswith("query/")},
                          query, kq2, train)
    m = jnp.tanh(ys @ params["att/w_ym"] + (u @ params["att/w_um"])[None])
    s = jax.nn.softmax((m @ params["att/w_ms"])[..., 0], axis=0)    # (Td, B)
    r = jnp.einsum("tb,tbh->bh", s, ys)                             # (B, 2H)
    g = jnp.tanh(r @ params["att/w_rg"] + u @ params["att/w_ug"])
    logits = g @ params["head/w"] + params["head/b"]
    upd = dict(upd_d)
    upd.update({f"query/{k}": v for k, v in upd_q.items()})
    return logits, upd


def init_attreader(cfg: ModelConfig, key) -> tuple[dict, dict]:
    """Params/state for the attentive reader: doc fwd ('l0/...'), doc bwd
    ('bwd/l0/...'), query fwd ('query/l0/...'), query bwd
    ('query/bwd/l0/...'), attention + head."""
    sub = dataclasses.replace(cfg, num_layers=1, head="attreader")
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    core, st = init_params(sub, k1)
    params = {k: v for k, v in core.items() if k.startswith("l0/")}
    state = dict(st)
    bwd_p, bwd_s = init_params(dataclasses.replace(sub, head="lm",
                                                   vocab=cfg.vocab), k2)
    params.update({f"bwd/{k}": v for k, v in bwd_p.items()
                   if k.startswith("l0/")})
    state.update({f"bwd/{k}": v for k, v in bwd_s.items()})
    qf_p, qf_s = init_params(dataclasses.replace(sub, head="lm"), k3)
    params.update({f"query/{k}": v for k, v in qf_p.items()
                   if k.startswith("l0/")})
    state.update({f"query/{k}": v for k, v in qf_s.items()})
    qb_p, qb_s = init_params(dataclasses.replace(sub, head="lm"), k4)
    params.update({f"query/bwd/{k}": v for k, v in qb_p.items()
                   if k.startswith("l0/")})
    state.update({f"query/bwd/{k}": v for k, v in qb_s.items()})
    params.update({k: v for k, v in core.items()
                   if k.startswith(("att/", "head/"))})
    if cfg.emb_dim:
        params["emb/emb"] = 0.1 * jax.random.normal(
            k5, (cfg.vocab, cfg.emb_dim), jnp.float32)
        params["query/emb/emb"] = params["emb/emb"]
        params["bwd/emb/emb"] = params["emb/emb"]
        params["query/bwd/emb/emb"] = params["emb/emb"]
    return params, state


# ---------------------------------------------------------------------------
# Pallas-kernel inference cell (deployment path)
# ---------------------------------------------------------------------------

def kernel_infer_step(cfg: ModelConfig, params, state, wq, x_t, h, c):
    """One deployment-path LSTM step through the fused Pallas cell.

    x_t: one-hot/continuous f32 (B, D). Only valid for single-layer
    bnlstm configs (the serving configuration); BN statistics are the
    folded running estimates.
    """
    assert cfg.num_layers == 1 and not cfg.is_gru
    p = "l0"
    phi_x = params[f"{p}/phi_x"]
    phi_h = params[f"{p}/phi_h"]
    sx, tx = fold_bn(state[f"{p}/rm_x"], state[f"{p}/rv_x"], phi_x,
                     jnp.zeros_like(phi_x))
    sh, th = fold_bn(state[f"{p}/rm_h"], state[f"{p}/rv_h"], phi_h,
                     jnp.zeros_like(phi_h))
    # Pallas cell uses gate order [i, f, g, o] — same as ours.
    return _pallas_cell(x_t, h, c, wq[f"{p}/wx"], wq[f"{p}/wh"],
                        sx, tx, sh, th, params[f"{p}/b"])
