"""AOT lowering: JAX (L2+L1) -> HLO text artifacts for the rust runtime.

For each registered experiment this emits:

    artifacts/<name>_<entry>.hlo.txt   one per entrypoint (train/eval/...)
    artifacts/<name>.meta.json         input/output binding + paper row
    artifacts/<name>.init.bin          raw f32 init values (params|state|opt)

HLO **text** is the interchange format (NOT lowered.compile() or a
serialized HloModuleProto): jax >= 0.5 emits protos with 64-bit
instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs exactly once, here. The rust binary is self-contained given
the artifacts directory.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import quantizers as Q
from . import train as T


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Experiment:
    """One named artifact bundle: a model + its entrypoints + paper row."""
    name: str
    task: str                      # charlm | wordlm | mnist | qa
    model: M.ModelConfig
    train: T.TrainConfig
    entries: tuple[str, ...] = ("train", "eval")
    # eval variants: list of (suffix, seq_len, batch)
    eval_variants: tuple = ()
    # infer variants: list of (suffix, batch)
    infer_variants: tuple = ()
    paper: dict = dataclasses.field(default_factory=dict)


REGISTRY: dict[str, Experiment] = {}


def _reg(e: Experiment):
    assert e.name not in REGISTRY, e.name
    REGISTRY[e.name] = e


# --- Table 1: char-level LSTM on PTB / War&Peace / Linux Kernel -----------
# Reduced scale: hidden 96 (paper: 1000/512/512), seq 50 (paper 100).
# paper[...] carries the published row so benches print paper-vs-measured.

_CHAR_CORPORA = {
    # corpus: (vocab, paper_hidden, paper rows {method: (bpc, size_kb)})
    "ptb": (50, 1000, {
        "fp": 1.39, "bin": 1.43, "bc": 2.51, "lab": 1.56, "ter": 1.39,
        "twn": 1.51, "ttq": 1.49, "laq2": 1.46, "laq3": 1.46, "laq4": 1.47,
        "dorefa3": 1.47, "dorefa4": 1.47}),
    "wp": (87, 512, {
        "fp": 1.72, "bin": 1.78, "bc": 5.10, "lab": 1.86, "ter": 1.72,
        "twn": 1.86, "ttq": 1.83, "laq2": 1.80, "laq3": 1.83, "laq4": 1.83,
        "dorefa3": 1.95, "dorefa4": 1.92}),
    "lk": (101, 512, {
        "fp": 1.73, "bin": 1.79, "bc": 4.24, "lab": 1.88, "ter": 1.75,
        "twn": 1.85, "ttq": 1.88, "laq2": 1.81, "laq3": 1.84, "laq4": 1.90,
        "dorefa3": 1.84, "dorefa4": 1.90}),
}

_CHAR_METHODS = ["fp", "bin", "ter", "bc", "lab", "twn", "ttq",
                 "laq2", "laq3", "laq4", "dorefa3", "dorefa4"]


def _char_arch(method: str) -> str:
    """Ours (bin/ter) use the paper's BN-LSTM; every baseline (and the FP
    reference) is the vanilla LSTM, as in the paper's comparisons."""
    return "bnlstm" if method in ("bin", "ter") else "lstm"


for corpus, (vocab, paper_h, rows) in _CHAR_CORPORA.items():
    for method in _CHAR_METHODS:
        _reg(Experiment(
            name=f"char_{corpus}_{method}",
            task="charlm",
            model=M.ModelConfig(arch=_char_arch(method), quantizer=method,
                                vocab=vocab, hidden=96),
            train=T.TrainConfig(optimizer="adam", seq_len=50, batch=32),
            paper={"table": 1, "hidden": paper_h, "seq_len": 100,
                   "metric": "bpc", "value": rows[method],
                   "bits": Q.bits(method)},
        ))

# extra entry points on the flagship PTB configs:
#   - gate statistics (Appendix A figs 4/5/6) for fp / bc / bin
#   - serving infer (batch 1 and 16) for fp / bin / ter
#   - eval at longer sequences (Fig 2b) for fp / bin / ter
#   - batch-size sweep training (Fig 3) for bin / ter / fp
for m in ("fp", "bc", "bin"):
    e = REGISTRY[f"char_ptb_{m}"]
    REGISTRY[e.name] = dataclasses.replace(e, entries=e.entries + ("gatestats",))
for m in ("fp", "bin", "ter"):
    e = REGISTRY[f"char_ptb_{m}"]
    REGISTRY[e.name] = dataclasses.replace(
        e,
        infer_variants=(("b1", 1), ("b16", 16)),
        eval_variants=(("len25", 25, 32), ("len100", 100, 32),
                       ("len200", 200, 16), ("len400", 400, 8)),
    )
for m in ("fp", "bin", "ter"):
    for b in (2, 8, 16, 64):
        base = REGISTRY[f"char_ptb_{m}"]
        _reg(Experiment(
            name=f"char_ptb_{m}_b{b}",
            task="charlm",
            model=base.model,
            train=dataclasses.replace(base.train, batch=b),
            paper={"figure": 3, "metric": "bpc"},
        ))

# --- Table 2: Text8 ---------------------------------------------------------
for method, bpc in (("fp", 1.46), ("bin", 1.54), ("ter", 1.51), ("bc", 2.45)):
    _reg(Experiment(
        name=f"char_text8_{method}",
        task="charlm",
        model=M.ModelConfig(arch=_char_arch(method), quantizer=method,
                            vocab=27, hidden=128),
        train=T.TrainConfig(optimizer="adam", seq_len=60, batch=32),
        paper={"table": 2, "hidden": 2000, "seq_len": 180,
               "metric": "bpc", "value": bpc, "bits": Q.bits(method)},
    ))

# --- Table 3: word-level PTB ------------------------------------------------
_WORD_SIZES = {
    # ours: (hidden, layers, dropout); paper: (hidden, layers)
    "small": (64, 1, 0.0, 300, 1),
    "medium": (128, 1, 0.35, 650, 2),
    "large": (192, 2, 0.45, 1500, 2),
}
_WORD_ROWS = {
    ("small", "fp"): 91.5, ("small", "bin"): 92.2, ("small", "ter"): 90.7,
    ("small", "bc"): 125.9, ("small", "alt2"): 103.1,
    ("small", "alt3"): 93.8, ("small", "alt4"): 91.4,
    ("medium", "fp"): 87.6, ("medium", "bin"): 87.2,
    ("medium", "ter"): 86.1, ("medium", "bc"): 108.4,
    ("large", "fp"): 78.5, ("large", "bin"): 76.5, ("large", "ter"): 76.3,
    ("large", "bc"): 128.5,
}
for (size, method), ppl in _WORD_ROWS.items():
    h, layers, drop, ph, pl_ = _WORD_SIZES[size]
    _reg(Experiment(
        name=f"word_{size}_{method}",
        task="wordlm",
        model=M.ModelConfig(arch=_char_arch(method), quantizer=method,
                            vocab=2000, emb_dim=h, hidden=h,
                            num_layers=layers, dropout=drop),
        train=T.TrainConfig(optimizer="sgd", grad_clip=0.25, seq_len=35,
                            batch=20),
        paper={"table": 3, "hidden": ph, "layers": pl_, "metric": "ppl",
               "value": ppl, "bits": Q.bits(method),
               "ops_multiplier": Q.OPS_MULTIPLIER.get(method, 1)},
    ))

# --- Table 4: sequential MNIST ---------------------------------------------
for method, acc in (("fp", 98.9), ("bin", 98.6), ("ter", 98.8),
                    ("bc", 68.3), ("alt2", 98.8)):
    _reg(Experiment(
        name=f"mnist_{method}",
        task="mnist",
        model=M.ModelConfig(arch=_char_arch(method), quantizer=method,
                            vocab=0, input_dim=1, hidden=100,
                            head="classifier", num_classes=10),
        train=T.TrainConfig(optimizer="adam", seq_len=784, batch=64),
        paper={"table": 4, "hidden": 100, "metric": "acc", "value": acc,
               "bits": Q.bits(method),
               "ops_multiplier": Q.OPS_MULTIPLIER.get(method, 1)},
    ))

# --- Table 5: CNN-QA attentive reader ---------------------------------------
for method, acc in (("fp", 59.81), ("bin", 59.22), ("ter", 60.03),
                    ("bc", 5.34)):
    _reg(Experiment(
        name=f"qa_{method}",
        task="qa",
        model=M.ModelConfig(arch=_char_arch(method), quantizer=method,
                            vocab=120, emb_dim=32, hidden=48,
                            head="attreader", num_classes=30),
        train=T.TrainConfig(optimizer="adam", seq_len=60, batch=32),
        paper={"table": 5, "hidden": 256, "metric": "acc", "value": acc,
               "bits": Q.bits(method)},
    ))

# --- Table 6: char-level GRU -------------------------------------------------
_GRU_ROWS = {
    ("ptb", "fp"): 1.40, ("ptb", "bin"): 1.46, ("ptb", "ter"): 1.41,
    ("wp", "fp"): 1.75, ("wp", "bin"): 1.92, ("wp", "ter"): 1.82,
    ("lk", "fp"): 1.82, ("lk", "bin"): 1.90, ("lk", "ter"): 1.81,
}
for (corpus, method), bpc in _GRU_ROWS.items():
    vocab, paper_h, _ = _CHAR_CORPORA[corpus]
    arch = "bngru" if method in ("bin", "ter") else "gru"
    _reg(Experiment(
        name=f"gru_{corpus}_{method}",
        task="charlm",
        model=M.ModelConfig(arch=arch, quantizer=method, vocab=vocab,
                            hidden=96),
        train=T.TrainConfig(optimizer="adam", seq_len=50, batch=32),
        paper={"table": 6, "hidden": paper_h, "metric": "bpc",
               "value": bpc, "bits": Q.bits(method)},
    ))


# ---------------------------------------------------------------------------
# lowering machinery
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


_DTYPE = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32",
          jnp.uint32.dtype: "u32"}


def _leaf_specs(tree, groups):
    """Flatten (dict|array)* example args into ordered [(group, name,
    shape, dtype)] matching jax's flatten order (sorted dict keys)."""
    specs = []
    for group, obj in groups:
        if isinstance(obj, dict):
            for k in sorted(obj.keys()):
                v = obj[k]
                specs.append({"group": group, "name": k,
                              "shape": list(v.shape),
                              "dtype": _DTYPE[v.dtype]})
        else:
            specs.append({"group": group, "name": group,
                          "shape": list(obj.shape),
                          "dtype": _DTYPE[obj.dtype]})
    return specs


def _out_specs(out_tree):
    """Output leaf specs via tree flatten with paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(out_tree)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        specs.append({"name": name or "out", "shape": list(leaf.shape),
                      "dtype": _DTYPE[jnp.dtype(leaf.dtype)]})
    return specs


def _example_data(e: Experiment, seq_len=None, batch=None):
    """Zero-valued example arrays with the artifact's data shapes."""
    tl = seq_len or e.train.seq_len
    b = batch or e.train.batch
    m = e.model
    if e.task == "qa":
        doc = jnp.zeros((tl, b), jnp.int32)
        query = jnp.zeros((10, b), jnp.int32)
        y = jnp.zeros((b,), jnp.int32)
        return {"doc": doc, "query": query, "y": y}
    if m.head == "classifier":
        x = jnp.zeros((tl, b, m.input_dim), jnp.float32)
        y = jnp.zeros((b,), jnp.int32)
    else:
        x = jnp.zeros((tl, b), jnp.int32)
        y = jnp.zeros((tl, b), jnp.int32)
    return {"x": x, "y": y}


def _init_bundle(e: Experiment, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    if e.model.head == "attreader":
        params, state = M.init_attreader(e.model, key)
    else:
        params, state = M.init_params(e.model, key)
    opt = T.init_opt(e.train, params)
    return params, state, opt


def _footprint(e: Experiment) -> dict:
    """Recurrent-weight memory accounting at OUR scale; the paper-scale
    Size columns are recomputed rust-side from paper dims + bits."""
    m = e.model
    n_params = 0
    # include every quantized matrix (attreader has 4 directional LSTMs)
    dummy_params, _, _ = _init_bundle(e)
    rec = [k for k in dummy_params
           if k.endswith(("/wx", "/wh")) and "att/" not in k]
    for k in rec:
        n_params += int(np.prod(dummy_params[k].shape))
    return {
        "recurrent_params": n_params,
        "bytes_fp32": n_params * 4,
        "bytes_quant": int(n_params * Q.bits(e.model.quantizer) / 8),
        "recurrent_names": sorted(rec),
    }


def lower_experiment(e: Experiment, outdir: str, verbose: bool = True):
    params, state, opt = _init_bundle(e)
    seed = jnp.zeros((), jnp.int32)
    lr = jnp.asarray(0.001, jnp.float32)
    meta = {
        "name": e.name,
        "task": e.task,
        "model": dataclasses.asdict(e.model),
        "train": dataclasses.asdict(e.train),
        "paper": e.paper,
        "bits_per_weight": Q.bits(e.model.quantizer),
        "footprint": _footprint(e),
        "entrypoints": {},
    }

    def emit(entry_name, fn, groups, fname_suffix):
        t0 = time.time()
        example = [obj for _, obj in groups]
        # keep_unused: the HLO signature must carry EVERY leaf (even ones a
        # given entrypoint ignores, e.g. the softmax head in gatestats) so
        # the rust binding can use one uniform input order per bundle.
        lowered = jax.jit(fn, keep_unused=True).lower(*example)
        text = to_hlo_text(lowered)
        out_shape = jax.eval_shape(fn, *example)
        hlo_file = f"{e.name}_{fname_suffix}.hlo.txt"
        with open(os.path.join(outdir, hlo_file), "w") as f:
            f.write(text)
        meta["entrypoints"][entry_name] = {
            "hlo": hlo_file,
            "inputs": _leaf_specs(None, groups),
            "outputs": _out_specs(out_shape),
        }
        if verbose:
            print(f"  {e.name}:{entry_name}  {len(text)/1e6:.2f} MB "
                  f"({time.time()-t0:.1f}s)", flush=True)

    data = _example_data(e)
    if e.task == "qa":
        if "train" in e.entries:
            step = T.build_attreader_train_step(e.model, e.train)
            emit("train", step,
                 [("params", params), ("state", state), ("opt", opt),
                  ("doc", data["doc"]), ("query", data["query"]),
                  ("y", data["y"]), ("seed", seed), ("lr", lr)], "train")
        if "eval" in e.entries:
            step = T.build_attreader_eval_step(e.model)
            emit("eval", step,
                 [("params", params), ("state", state),
                  ("doc", data["doc"]), ("query", data["query"]),
                  ("y", data["y"]), ("seed", seed)], "eval")
    else:
        if "train" in e.entries:
            step = T.build_train_step(e.model, e.train)
            emit("train", step,
                 [("params", params), ("state", state), ("opt", opt),
                  ("x", data["x"]), ("y", data["y"]), ("seed", seed),
                  ("lr", lr)], "train")
        if "eval" in e.entries:
            step = T.build_eval_step(e.model)
            emit("eval", step,
                 [("params", params), ("state", state), ("x", data["x"]),
                  ("y", data["y"]), ("seed", seed)], "eval")
        if "gatestats" in e.entries:
            step = T.build_gate_stats_step(e.model)
            emit("gatestats", step,
                 [("params", params), ("state", state), ("x", data["x"]),
                  ("seed", seed)], "gatestats")
        for suffix, sl, b in e.eval_variants:
            step = T.build_eval_step(e.model)
            d = _example_data(e, seq_len=sl, batch=b)
            emit(f"eval_{suffix}", step,
                 [("params", params), ("state", state), ("x", d["x"]),
                  ("y", d["y"]), ("seed", seed)], f"eval_{suffix}")
        for suffix, b in e.infer_variants:
            step = T.build_infer_step(e.model)
            x1 = jnp.zeros((b, e.model.layer_input_dim(0)), jnp.float32)
            h1 = jnp.zeros((b, e.model.hidden), jnp.float32)
            c1 = jnp.zeros((b, e.model.hidden), jnp.float32)
            emit(f"infer_{suffix}", step,
                 [("params", params), ("state", state), ("x", x1),
                  ("h", h1), ("c", c1), ("seed", seed)], f"infer_{suffix}")

    # init.bin: params | state | opt, each name-sorted, raw f32 LE.
    segments = []
    offset = 0
    blobs = []
    for group, d in (("params", params), ("state", state), ("opt", opt)):
        for k in sorted(d.keys()):
            arr = np.asarray(d[k], np.float32)
            segments.append({"group": group, "name": k,
                             "shape": list(arr.shape), "dtype": "f32",
                             "offset": offset, "nbytes": arr.nbytes})
            blobs.append(arr.tobytes())
            offset += arr.nbytes
    init_file = f"{e.name}.init.bin"
    with open(os.path.join(outdir, init_file), "wb") as f:
        f.write(b"".join(blobs))
    meta["init"] = {"file": init_file, "total_bytes": offset,
                    "segments": segments}

    with open(os.path.join(outdir, f"{e.name}.meta.json"), "w") as f:
        json.dump(meta, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only", nargs="*", default=[])
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    if args.list:
        for name in sorted(REGISTRY):
            print(name)
        return

    names = sorted(REGISTRY) if args.all else args.only
    if not names:
        print("nothing to do: pass --all or --only <names>", file=sys.stderr)
        sys.exit(1)
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    for i, name in enumerate(names):
        print(f"[{i+1}/{len(names)}] {name}", flush=True)
        lower_experiment(REGISTRY[name], args.out)
    print(f"done: {len(names)} experiments in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
